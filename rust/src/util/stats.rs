//! Streaming statistics used by the network monitor and metrics.

/// Exponentially-weighted moving average — the estimator `DeCo` consumes for
/// the measured bandwidth `a`, latency `b` and compute time `T_comp`.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// L2 norm of a float slice (f64 accumulation — the gradient-norm metric).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Squared L2 norm with f64 accumulation.
pub fn l2_norm_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        e.update(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn online_stats_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn l2_norm_basic() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }
}
