//! Deterministic, dependency-free RNG.
//!
//! Two generators:
//! * [`SplitMix64`] — the stream used for cross-language golden fixtures
//!   (python/tests/test_aot.py writes `artifacts/golden_compress.json` from
//!   the *identical* bit-for-bit sequence).
//! * [`Rng`] — xoshiro256++ for everything else (fast, good equidistribution,
//!   seedable per worker so experiments are reproducible).

/// SplitMix64: the canonical seeding/golden-fixture stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [-1, 1): bit-for-bit identical to the python fixture
    /// generator (`(z >> 11) / 2^53 * 2 - 1`, computed in f64, cast to f32).
    #[inline]
    pub fn next_f32_sym(&mut self) -> f32 {
        let z = self.next_u64();
        (((z >> 11) as f64) / ((1u64 << 53) as f64) * 2.0 - 1.0) as f32
    }

    pub fn fill_f32_sym(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_f32_sym();
        }
    }
}

/// xoshiro256++ — general-purpose deterministic RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // seed the state through SplitMix64 per Vigna's recommendation
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) / ((1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair not kept — simplicity
    /// beats the 2x throughput here; the hot paths never draw normals).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        let k = k.min(n);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // first outputs for seed 1234567 (reference values from the
        // published SplitMix64 algorithm)
        let mut r = SplitMix64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        let mut r2 = SplitMix64::new(0);
        assert_eq!(r2.next_u64(), a);
        assert_eq!(r2.next_u64(), b);
    }

    #[test]
    fn splitmix_f32_range() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let v = r.next_f32_sym();
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rng_uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
