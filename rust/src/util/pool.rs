//! Scoped worker pool — the std-only parallel substrate for the coordinator
//! hot loop and the experiment sweeps (DESIGN.md §Parallel-Execution).
//!
//! Built on [`std::thread::scope`], so parallel regions may borrow stack
//! data (worker states, model shards) without `Arc` or lifetime erasure.
//! The pool object itself is just a reusable size policy: each region
//! spawns scoped threads and joins them before returning, which keeps the
//! API safe and panic-propagating at the cost of a thread spawn per region
//! (~tens of µs) — negligible against the ≥ ms-scale regions the training
//! loop hands it, and the loop falls back to inline execution below
//! [`crate::coordinator`]'s size thresholds.
//!
//! Determinism contract: none of these primitives change *what* is
//! computed, only *where*. Work is split into contiguous chunks with fixed
//! boundaries (a pure function of `len` and `threads`), and `map` returns
//! results in input order, so callers that reduce in a fixed order get
//! bit-identical results at any pool size.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A reusable scoped-thread worker pool.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool running `threads` ways (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Pool size 1: every primitive runs inline on the caller.
    pub const fn serial() -> Self {
        Self { threads: 1 }
    }

    /// Sized from the machine: `available_parallelism`, capped at 16 (the
    /// per-region spawn cost grows linearly with threads and the hot-loop
    /// shapes saturate well before that).
    pub fn with_default_parallelism() -> Self {
        Self::new(Self::default_threads())
    }

    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(16)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `items` into ≤ `threads` contiguous chunks and run
    /// `f(start_index, chunk)` on each in parallel. Chunk boundaries depend
    /// only on `(items.len(), threads)`.
    pub fn for_each_chunk_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let parts = self.threads.min(n);
        if parts == 1 {
            f(0, items);
            return;
        }
        let chunk = n.div_ceil(parts);
        std::thread::scope(|s| {
            let f = &f;
            let mut chunks = items.chunks_mut(chunk);
            let first = chunks.next().expect("n > 0");
            for (i, c) in chunks.enumerate() {
                let start = (i + 1) * chunk;
                s.spawn(move || f(start, c));
            }
            // the caller works the first chunk instead of idling at the
            // scope join — one fewer spawn per region
            f(0, first);
        });
    }

    /// Like [`Self::for_each_chunk_mut`] over two equal-length slices
    /// chunked identically: `f(start_index, a_chunk, b_chunk)`. This is the
    /// sharded-aggregation primitive — `a` is the reduction buffer, `b` the
    /// model, and each shard is owned by exactly one thread.
    pub fn zip_chunk_mut<A, B, F>(&self, a: &mut [A], b: &mut [B], f: F)
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut [A], &mut [B]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "zip_chunk_mut: length mismatch");
        let n = a.len();
        if n == 0 {
            return;
        }
        let parts = self.threads.min(n);
        if parts == 1 {
            f(0, a, b);
            return;
        }
        let chunk = n.div_ceil(parts);
        std::thread::scope(|s| {
            let f = &f;
            let mut pairs = a.chunks_mut(chunk).zip(b.chunks_mut(chunk));
            let (fa, fb) = pairs.next().expect("n > 0");
            for (i, (ca, cb)) in pairs.enumerate() {
                let start = (i + 1) * chunk;
                s.spawn(move || f(start, ca, cb));
            }
            f(0, fa, fb);
        });
    }

    /// Parallel `(0..n).map(f)` preserving input order. Indices are handed
    /// out dynamically (work stealing over an atomic counter), so uneven
    /// tasks — e.g. training runs of different lengths — load-balance.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.threads == 1 || n == 1 {
            return (0..n).map(|i| f(i)).collect();
        }
        let next = AtomicUsize::new(0);
        let mut pairs: Vec<(usize, T)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let f = &f;
            let next = &next;
            let helpers = self.threads.min(n) - 1;
            let handles: Vec<_> = (0..helpers)
                .map(|_| {
                    s.spawn(move || {
                        let mut local = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                pairs.push((i, f(i)));
            }
            for h in handles {
                pairs.extend(h.join().expect("pool worker panicked"));
            }
        });
        pairs.sort_by_key(|p| p.0);
        pairs.into_iter().map(|p| p.1).collect()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::with_default_parallelism()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_run_concurrently_and_cover_items() {
        // with >= items worth of threads, every item lands in its own chunk
        let pool = WorkerPool::new(4);
        let mask = AtomicU64::new(0);
        let mut items = [0u64, 1, 2, 3];
        pool.for_each_chunk_mut(&mut items, |start, chunk| {
            assert_eq!(chunk.len(), 1);
            mask.fetch_or(1 << (start as u64), Ordering::Relaxed);
        });
        assert_eq!(mask.load(Ordering::Relaxed), 0b1111);
    }

    #[test]
    fn chunks_touch_each_item_once_with_correct_index() {
        for threads in [1usize, 2, 3, 8] {
            for n in [0usize, 1, 5, 16, 17] {
                let pool = WorkerPool::new(threads);
                let mut items: Vec<usize> = vec![usize::MAX; n];
                pool.for_each_chunk_mut(&mut items, |start, chunk| {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = start + j; // global index
                    }
                });
                let want: Vec<usize> = (0..n).collect();
                assert_eq!(items, want, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn zip_chunks_align() {
        for threads in [1usize, 2, 5] {
            let pool = WorkerPool::new(threads);
            let n = 23;
            let mut a: Vec<u32> = (0..n as u32).collect();
            let mut b: Vec<u32> = vec![0; n];
            pool.zip_chunk_mut(&mut a, &mut b, |start, ca, cb| {
                assert_eq!(ca.len(), cb.len());
                for (j, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate()
                {
                    assert_eq!(*x as usize, start + j);
                    *y = *x * 2;
                }
            });
            let want: Vec<u32> = (0..n as u32).map(|v| v * 2).collect();
            assert_eq!(b, want, "threads={threads}");
        }
    }

    #[test]
    fn map_preserves_order() {
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let out = pool.map(37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
        assert!(WorkerPool::new(4).map(0, |i| i).is_empty());
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::serial();
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let mut items = [0u8; 3];
        pool.for_each_chunk_mut(&mut items, |_, _| {
            assert_eq!(std::thread::current().id(), caller);
        });
    }

    #[test]
    fn default_sizing_sane() {
        let t = WorkerPool::default_threads();
        assert!(t >= 1 && t <= 16);
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }
}
