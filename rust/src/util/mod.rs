//! Small shared substrate: deterministic RNG, streaming statistics, a JSON
//! codec, a bench harness, a scoped worker pool, and a property-testing
//! helper — all in-tree because this repo builds fully offline (see
//! Cargo.toml).

pub mod bench;
pub mod check;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use pool::WorkerPool;
pub use rng::{Rng, SplitMix64};
pub use stats::{Ewma, OnlineStats};
