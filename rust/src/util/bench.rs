//! Mini bench harness (criterion is not in the offline vendored set, so the
//! `cargo bench` targets use this): warmup, adaptive iteration count,
//! median/mean/σ over samples, throughput reporting, and a stable text
//! output format the perf pass diff's against.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    /// minimum measurement time per benchmark
    min_time: Duration,
    samples: usize,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub std_ns: f64,
    pub iters_per_sample: u64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // fast mode for CI smoke: DECO_BENCH_FAST=1 shrinks measurement time
        let fast = std::env::var("DECO_BENCH_FAST").is_ok();
        Self {
            group: group.to_string(),
            min_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(400)
            },
            samples: if fast { 5 } else { 15 },
        }
    }

    /// Time `f`, which performs ONE logical operation per call.
    pub fn bench(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        let res = self.bench_quiet(name, &mut f);
        println!("{}", format_result(&res, None));
        dump_json(&res, None);
        res
    }

    /// Like `bench` but also reports bytes/s throughput.
    pub fn bench_bytes(
        &self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut(),
    ) -> BenchResult {
        let res = self.bench_quiet(name, &mut f);
        println!("{}", format_result(&res, Some(bytes)));
        dump_json(&res, Some(bytes));
        res
    }

    fn bench_quiet(&self, name: &str, f: &mut impl FnMut()) -> BenchResult {
        // warmup + calibrate iters per sample
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.min_time / 4 {
            f();
            calib_iters += 1;
        }
        let per_call = (t0.elapsed().as_nanos() as f64
            / calib_iters.max(1) as f64)
            .max(1.0);
        let target_sample_ns =
            (self.min_time.as_nanos() as f64 / self.samples as f64).max(1e5);
        let iters = ((target_sample_ns / per_call) as u64).max(1);
        let mut sample_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_ns.push(s.elapsed().as_nanos() as f64 / iters as f64);
        }
        sample_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
        let median = sample_ns[sample_ns.len() / 2];
        let var = sample_ns
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / sample_ns.len() as f64;
        BenchResult {
            name: format!("{}/{}", self.group, name),
            mean_ns: mean,
            median_ns: median,
            std_ns: var.sqrt(),
            iters_per_sample: iters,
        }
    }
}

/// When `DECO_BENCH_JSON=path` is set, append one JSON object per result —
/// `scripts/bench.sh` consolidates these into `BENCH_pipeline.json` so PRs
/// have a machine-readable perf trajectory to diff against.
fn dump_json(r: &BenchResult, bytes: Option<u64>) {
    use std::io::Write;
    let Ok(path) = std::env::var("DECO_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    else {
        return;
    };
    let throughput = bytes
        .map(|b| format!(",\"bytes_per_sec\":{:.0}", b as f64 / r.median_ns * 1e9))
        .unwrap_or_default();
    let _ = writeln!(
        f,
        "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"median_ns\":{:.1},\
         \"std_ns\":{:.1},\"iters_per_sample\":{}{}}}",
        r.name, r.mean_ns, r.median_ns, r.std_ns, r.iters_per_sample, throughput
    );
}

fn human_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn format_result(r: &BenchResult, bytes: Option<u64>) -> String {
    let mut line = format!(
        "{:<44} {:>12} (median {:>12}, sd {:>10})",
        r.name,
        human_time(r.mean_ns),
        human_time(r.median_ns),
        human_time(r.std_ns),
    );
    if let Some(b) = bytes {
        let gbps = b as f64 / r.median_ns; // bytes/ns == GB/s
        line.push_str(&format!("  {:>8.2} GB/s", gbps));
    }
    line
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("DECO_BENCH_FAST", "1");
        let b = Bench::new("test");
        let mut acc = 0u64;
        let r = b.bench("noop_loop", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns > 0.0);
        assert!(r.iters_per_sample >= 1);
        black_box(acc);
    }

    #[test]
    fn human_units() {
        assert!(human_time(500.0).contains("ns"));
        assert!(human_time(5e4).contains("us"));
        assert!(human_time(5e7).contains("ms"));
        assert!(human_time(5e9).contains("s"));
    }
}
