//! Tiny property-testing helper (proptest is not in the offline vendored
//! set). `forall` drives a closure with N seeded RNGs; on failure it reports
//! the failing seed so the case can be replayed deterministically, and
//! greedily shrinks any `usize` sizes drawn through [`Gen`].

use super::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// usize in [lo, hi] inclusive.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// f32 vector of standard normals.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.rng.fill_normal_f32(&mut v, scale);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` seeded generators. The property returns
/// `Err(description)` to fail. Panics with the failing seed on failure.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // optional env override for deeper local runs
    let cases = std::env::var("DECO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        let seed = 0x5EED_0000_0000u64 + case as u64;
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay: Gen {{ rng: Rng::new({seed:#x}), seed: {seed:#x} }}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("abs_nonneg", 50, |g| {
            let n = g.size(1, 64);
            let v = g.normal_vec(n, 2.0);
            if v.iter().all(|x| x.abs() >= 0.0) {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn reports_failing_seed() {
        forall("always_fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn gen_ranges() {
        forall("gen_ranges", 100, |g| {
            let n = g.size(3, 7);
            if !(3..=7).contains(&n) {
                return Err(format!("size {n} out of range"));
            }
            let x = g.f64(-1.0, 1.0);
            if !(-1.0..1.0).contains(&x) {
                return Err(format!("f64 {x} out of range"));
            }
            Ok(())
        });
    }
}
