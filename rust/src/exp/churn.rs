//! `exp churn` — elastic-membership study (beyond the paper: it assumes a
//! fixed worker set and always-up links; real cross-region training sees
//! preemptions and transient outages).
//!
//! Sweeps churn rate × outage duration × strategy on the straggler fabric:
//! worker 0 (the bottleneck: ¼ bandwidth, 4× latency) cyclically leaves and
//! rejoins, and worker 1's link optionally suffers outages while the
//! straggler is present. Every membership event moves the effective
//! bottleneck `(a, b)` under the planner: when the straggler departs, the
//! active set is healthy and the conservative plan (tiny δ, deep τ) wastes
//! convergence per iteration; when it rejoins, a stale aggressive plan
//! stalls every iteration on the slow link. The comparison is **DeCo
//! (event)** — re-solving the moment the membership epoch moves — against
//! **DeCo (boundary)**, the same controller waiting for its `E` boundary
//! (E = 400 iterations ≈ 80 s here, so events routinely strike mid-window).
//! The `recovery` column is `t(boundary) / t(event)`: how much
//! event-triggered re-planning wins back. `slowdown` is the degradation of
//! each arm against its own calm (no-churn) run.
//!
//! Deterministic by construction: constant base trace, pinned T_comp, the
//! analytic quadratic oracle, and a seeded churn compiler —
//! `tests/elastic.rs` asserts two sweeps produce byte-identical CSV.

use crate::config::{FabricSpec, NetworkConfig};
use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::DecoInput;
use crate::elastic::{ChurnEvent, ChurnSpec, TimedEvent};
use crate::exp::{results_dir, speedup};
use crate::metrics::{format_table, RunResult};
use crate::netsim::{Fabric, TraceKind};
use crate::optim::Quadratic;
use crate::strategy::{PlanBasis, StrategyKind};
use crate::util::WorkerPool;

/// Base (healthy-link) network: 100 Mbps, 150 ms — same as `exp hetero`.
const BASE_BPS: f64 = 1e8;
const BASE_LAT: f64 = 0.15;
/// Straggler severity for worker 0: ¼ bandwidth, 4× latency.
const STRAG_FRAC: f64 = 0.25;
const STRAG_MULT: f64 = 4.0;
/// Pinned per-iteration compute time (s).
const T_COMP: f64 = 0.2;
/// Pinned gradient size (bits): a full gradient costs exactly one T_comp on
/// a healthy link, so both planner channels (δ and τ) matter.
const S_G: f64 = 2e7;
const GAMMA: f32 = 0.02;
/// Same loss target as the quadratic TaskSpec.
const TARGET: f64 = 0.18;
/// DeCo refresh period (iterations): ≈ 80 s of virtual time at T_comp, so
/// churn events routinely strike mid-window and boundary-only re-planning
/// runs stale for most of it.
const UPDATE_EVERY: usize = 400;
/// Upper bound on any arm's per-iteration virtual time in this setup
/// (T_comp 0.2 + straggler transmission 0.8 + latency 0.6, with outage
/// stalls amortized well under the slack) — sizes the churn horizon so
/// events cover the *whole* run at any `--scale`.
const PER_ITER_BOUND_S: f64 = 2.0;

/// Churn generation horizon for a run of `max_iters` iterations:
/// comfortably past the slowest arm's end, so no scenario silently goes
/// calm partway through a long run.
fn horizon_for(max_iters: usize) -> f64 {
    max_iters as f64 * PER_ITER_BOUND_S
}

/// Scripted periodic churn over `[0, horizon_s)`: each cycle the straggler
/// (worker 0) leaves at 25% and rejoins at 75% of the cycle; with
/// `outage_s > 0`, worker 1's link goes down right after the rejoin (while
/// the straggler gates the pipeline — the compound-fault case).
pub fn cycle_spec(cycle_s: f64, outage_s: f64, horizon_s: f64) -> ChurnSpec {
    let mut events = Vec::new();
    let mut t = 0.0;
    while t + cycle_s <= horizon_s {
        events.push(TimedEvent {
            t: t + 0.25 * cycle_s,
            event: ChurnEvent::Leave { worker: 0 },
        });
        events.push(TimedEvent {
            t: t + 0.75 * cycle_s,
            event: ChurnEvent::Rejoin { worker: 0 },
        });
        if outage_s > 0.0 {
            events.push(TimedEvent {
                t: t + 0.8 * cycle_s,
                event: ChurnEvent::LinkOutage { worker: 1, secs: outage_s },
            });
        }
        t += cycle_s;
    }
    ChurnSpec::Scripted { events }
}

/// The straggler base fabric every churn cell starts from; the sweep
/// builds it once and clones it per cell (each run bakes its own fault
/// windows into its clone).
fn base_fabric(workers: usize) -> anyhow::Result<Fabric> {
    let net = NetworkConfig {
        trace: TraceKind::Constant { bps: BASE_BPS },
        latency_s: BASE_LAT,
        fabric: FabricSpec::Straggler { frac: STRAG_FRAC, mult: STRAG_MULT },
        topology: crate::config::TopologySpec::Flat,
        bonds: Vec::new(),
        losses: Vec::new(),
    };
    net.build_fabric(workers)
}

/// One training run on the straggler fabric under `spec`. `dim` is exposed
/// so the tests can shrink the oracle.
pub fn run_one(
    spec: &ChurnSpec,
    kind: StrategyKind,
    workers: usize,
    dim: usize,
    max_iters: usize,
    seed: u64,
) -> anyhow::Result<RunResult> {
    run_on(base_fabric(workers)?, spec, kind, dim, max_iters, seed)
}

/// One training run on a prebuilt fabric clone (the sweep-cell body).
fn run_on(
    fabric: Fabric,
    spec: &ChurnSpec,
    kind: StrategyKind,
    dim: usize,
    max_iters: usize,
    seed: u64,
) -> anyhow::Result<RunResult> {
    let workers = fabric.workers();
    let oracle = Quadratic::new(dim, workers, 0.5, 0.1, 0.3, 0.2, seed);
    let params = TrainParams {
        gamma: GAMMA,
        max_iters,
        log_every: 5,
        loss_target: Some(TARGET),
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        seed,
        fallback: DecoInput { s_g: S_G, a: BASE_BPS, b: BASE_LAT, t_comp: T_COMP },
        plan: PlanBasis::Bottleneck,
        // runs fan out run-level over the pool (the sweep_strategies
        // pattern); each inner loop stays serial
        threads: Some(1),
        churn: spec.clone(),
        ..Default::default()
    };
    let mut tl =
        TrainLoop::try_with_fabric(oracle, kind.build(), fabric, params)?;
    Ok(tl.run("quadratic"))
}

fn arms() -> Vec<(&'static str, StrategyKind)> {
    vec![
        ("D-SGD", StrategyKind::DSgd),
        ("DeCo (boundary)", StrategyKind::DecoSgd { update_every: UPDATE_EVERY }),
        ("DeCo (event)", StrategyKind::DecoEvent { update_every: UPDATE_EVERY }),
    ]
}

/// Scenario ladder: (label, spec). Labels are comma-free — they land in
/// the first CSV column verbatim. `(cycle_s, outage_s)` = (0, 0) encodes
/// the calm row and the seeded-random row.
fn scenarios(seed: u64, horizon_s: f64) -> Vec<(String, f64, f64, ChurnSpec)> {
    let mut out = vec![("calm".to_string(), 0.0, 0.0, ChurnSpec::None)];
    for cycle_s in [120.0, 60.0] {
        for outage_s in [0.0, 15.0] {
            let label = if outage_s > 0.0 {
                format!("cycle {cycle_s:.0}s + outage {outage_s:.0}s")
            } else {
                format!("cycle {cycle_s:.0}s")
            };
            out.push((
                label,
                cycle_s,
                outage_s,
                cycle_spec(cycle_s, outage_s, horizon_s),
            ));
        }
    }
    out.push((
        "random churn".to_string(),
        0.0,
        10.0,
        ChurnSpec::Random {
            leave_rate_per_100s: 2.0,
            mean_down_s: 25.0,
            outage_rate_per_100s: 1.0,
            outage_s: 10.0,
            horizon_s,
            seed,
        },
    ));
    out
}

/// The full sweep: returns `(csv, table_rows)`. Deterministic in
/// `(scale, workers, dim, seed)` — the determinism contract `tests/
/// elastic.rs` checks byte-for-byte.
pub fn sweep(
    scale: f64,
    workers: usize,
    dim: usize,
    seed: u64,
) -> anyhow::Result<(String, Vec<Vec<String>>)> {
    let max_iters = ((6000.0 * scale) as usize).max(50);
    let arms = arms();
    let sc = scenarios(seed, horizon_for(max_iters));
    // one base fabric for the whole sweep, cloned per cell — each cell
    // bakes its own churn windows into its clone
    let fabric = base_fabric(workers)?;
    let n_combos = sc.len() * arms.len();
    let pool = WorkerPool::new(WorkerPool::default_threads().min(n_combos));
    eprintln!("[churn] {n_combos} runs across {} threads", pool.threads());
    let results = pool.map(n_combos, |i| {
        let (_, _, _, spec) = &sc[i / arms.len()];
        let (_, kind) = &arms[i % arms.len()];
        run_on(fabric.clone(), spec, kind.clone(), dim, max_iters, seed)
    });
    let mut results = results.into_iter();
    let mut csv = String::from(
        "scenario,cycle_s,outage_s,strategy,time_to_target,total_iters,\
         slowdown_vs_calm\n",
    );
    let mut rows = Vec::new();
    // calm times per arm, for the degradation column
    let mut calm: Vec<Option<f64>> = Vec::new();
    for (label, cycle_s, outage_s, _) in &sc {
        let mut cells = vec![label.clone()];
        let mut times: Vec<Option<f64>> = Vec::new();
        for (ai, (arm, _)) in arms.iter().enumerate() {
            let res = results.next().expect("one result per combo")?;
            let t = res.time_to_loss(TARGET);
            if label == "calm" {
                calm.push(t);
            }
            let slowdown = match (calm.get(ai).copied().flatten(), t) {
                (Some(c), Some(t)) if c > 0.0 => format!("{:.2}", t / c),
                _ => "-".into(),
            };
            csv.push_str(&format!(
                "{label},{cycle_s},{outage_s},{arm},{},{},{slowdown}\n",
                t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                res.total_iters
            ));
            cells.push(
                t.map(|v| format!("{v:.1}s")).unwrap_or_else(|| "-".into()),
            );
            times.push(t);
        }
        // recovery of event-triggered re-planning over boundary-only
        cells.push(speedup(times[1], times[2]));
        rows.push(cells);
    }
    Ok((csv, rows))
}

pub fn main(scale: f64, workers: usize, seed: u64) -> anyhow::Result<()> {
    println!(
        "exp churn — churn rate x outage duration x strategy on a \
         {workers}-worker straggler fabric\n(base {:.0} Mbps / {BASE_LAT} s; \
         worker 0 = straggler at 1/4 bw, 4x lat, cycling leave/rejoin; \
         time-to-loss {TARGET} on the quadratic; DeCo E = {UPDATE_EVERY})\n",
        BASE_BPS / 1e6
    );
    let (csv, rows) = sweep(scale, workers, 4096, seed)?;
    println!(
        "{}",
        format_table(
            &["scenario", "D-SGD", "DeCo (boundary)", "DeCo (event)", "recovery"],
            &rows
        )
    );
    let path = results_dir().join("churn.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_ladder_shape() {
        let sc = scenarios(7, 2000.0);
        assert_eq!(sc.len(), 6);
        assert!(sc[0].3.is_none());
        assert!(sc.iter().all(|(label, ..)| !label.contains(',')));
        // every scripted spec compiles for a 4-worker run
        for (_, _, _, spec) in &sc {
            assert!(spec.compile(4).is_ok());
        }
    }

    #[test]
    fn horizon_scales_with_run_length() {
        // churn must cover the whole run at any --scale: the last scripted
        // cycle starts within one cycle of the per-iteration time bound
        for max_iters in [300usize, 6000, 18000] {
            let h = horizon_for(max_iters);
            assert!(h >= max_iters as f64 * PER_ITER_BOUND_S);
            let tl = cycle_spec(120.0, 15.0, h).compile(4).unwrap();
            let last = tl.events().last().unwrap().t;
            assert!(
                last >= h - 2.0 * 120.0,
                "events end at {last} but the horizon is {h}"
            );
        }
    }

    #[test]
    fn cycle_spec_alternates_and_repeats() {
        let spec = cycle_spec(100.0, 10.0, 2000.0);
        let tl = spec.compile(4).unwrap();
        let leaves = tl
            .events()
            .iter()
            .filter(|e| matches!(e.event, ChurnEvent::Leave { .. }))
            .count();
        let rejoins = tl
            .events()
            .iter()
            .filter(|e| matches!(e.event, ChurnEvent::Rejoin { .. }))
            .count();
        assert_eq!(leaves, rejoins);
        assert_eq!(leaves, 20, "2000 s horizon / 100 s cycle");
        assert_eq!(tl.events()[0].t, 25.0);
    }

    #[test]
    fn event_triggered_beats_boundary_under_churn() {
        // the headline: with the straggler cycling, event-triggered DeCo
        // reaches the target sooner than boundary-only DeCo
        let spec = cycle_spec(120.0, 0.0, horizon_for(6000));
        let boundary = run_one(
            &spec,
            StrategyKind::DecoSgd { update_every: UPDATE_EVERY },
            4,
            512,
            6000,
            7,
        )
        .unwrap();
        let event = run_one(
            &spec,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
            4,
            512,
            6000,
            7,
        )
        .unwrap();
        let tb = boundary.time_to_loss(TARGET).expect("boundary reaches");
        let te = event.time_to_loss(TARGET).expect("event reaches");
        assert!(
            te < tb,
            "event-triggered {te:.1}s should beat boundary-only {tb:.1}s"
        );
    }

    #[test]
    fn calm_run_makes_event_and_boundary_identical() {
        // with no churn the epoch never moves, so the two DeCo arms are the
        // same controller — bit-identical runs
        let b = run_one(
            &ChurnSpec::None,
            StrategyKind::DecoSgd { update_every: UPDATE_EVERY },
            4,
            256,
            800,
            7,
        )
        .unwrap();
        let e = run_one(
            &ChurnSpec::None,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
            4,
            256,
            800,
            7,
        )
        .unwrap();
        assert_eq!(b.total_iters, e.total_iters);
        assert_eq!(b.total_time.to_bits(), e.total_time.to_bits());
        for (rb, re) in b.records.iter().zip(e.records.iter()) {
            assert_eq!(rb.loss.to_bits(), re.loss.to_bits());
        }
    }
}
