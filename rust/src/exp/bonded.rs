//! `exp bonded` — multi-path bonding study (beyond the paper: it assumes
//! one WAN link per worker; multi-homed deployments can stripe a gradient
//! across several provider paths and fail over between them).
//!
//! Worker 0 is dual-homed: a **fast** path (100 Mbps / 50 ms) that suffers
//! scripted mid-run outages, and a **slow but stable** path (20 Mbps /
//! 300 ms) that never fails. The sweep compares four arms under the same
//! outage schedule:
//!
//! * **D-SGD (fast path)** / **DeCo (fast path)** — single-homed on the
//!   fast link; every outage stalls the whole synchronous pipeline for
//!   (nearly) the full outage window;
//! * **DeCo (stable path)** — single-homed on the slow link; immune to the
//!   outages but pays the 5× thinner pipe on every iteration;
//! * **DeCo (bonded)** — both paths under the water-filling scheduler
//!   (DESIGN.md §Bonding); outages on the fast path shift the bits to the
//!   surviving slow path, so the run *degrades* instead of stalling.
//!
//! The headline is the `max_gap_s` column (the longest virtual-time gap
//! between consecutive progress records): under outage churn the bonded
//! arm's gap stays near its calm per-iteration cost while the fast-path
//! arms' gap grows to the outage length — and bonded still reaches the
//! loss target first end-to-end (beats the best single path).
//!
//! Deterministic by construction: constant traces, pinned T_comp, the
//! analytic quadratic oracle, scripted churn — `tests/bond.rs` asserts two
//! sweeps produce byte-identical CSV.

use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::DecoInput;
use crate::elastic::{ChurnEvent, ChurnSpec, TimedEvent};
use crate::exp::{results_dir, speedup};
use crate::metrics::{format_table, RunResult};
use crate::netsim::{BandwidthTrace, Bond, Fabric, Link, TraceKind};
use crate::optim::Quadratic;
use crate::strategy::{PlanBasis, StrategyKind};
use crate::util::WorkerPool;

/// The fast path: healthy 100 Mbps / 50 ms — also every other worker's
/// (only) link.
const FAST_BPS: f64 = 1e8;
const FAST_LAT: f64 = 0.05;
/// The slow-but-stable path: 20 Mbps / 300 ms, never fails.
const SLOW_BPS: f64 = 2e7;
const SLOW_LAT: f64 = 0.3;
/// Pinned per-iteration compute time (s).
const T_COMP: f64 = 0.2;
/// Pinned gradient size (bits): one full gradient = one T_comp on the fast
/// path, 1 s on the slow path, so both planner channels matter.
const S_G: f64 = 2e7;
const GAMMA: f32 = 0.02;
/// Same loss target as the quadratic TaskSpec.
const TARGET: f64 = 0.18;
/// DeCo refresh period (iterations) — short enough to adapt within an
/// outage cycle.
const UPDATE_EVERY: usize = 50;
/// Outage cycle: one fast-path outage every this many virtual seconds.
const CYCLE_S: f64 = 120.0;
/// Upper bound on any arm's per-iteration virtual time in this setup
/// (stable path: 0.2 comp + 1.0 tx + 0.3 lat; outage stalls amortized
/// under the slack) — sizes the churn horizon at any `--scale`.
const PER_ITER_BOUND_S: f64 = 3.0;

/// How worker 0 is attached to the WAN.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathMode {
    /// single-homed on the fast (outage-prone) link
    SingleFast,
    /// single-homed on the slow (stable) link
    SingleStable,
    /// dual-homed: fast + slow under the water-filling bond
    Bonded,
}

fn fast_link() -> Link {
    Link::new(
        BandwidthTrace::new(TraceKind::Constant { bps: FAST_BPS }),
        FAST_LAT,
    )
}

fn slow_link() -> Link {
    Link::new(
        BandwidthTrace::new(TraceKind::Constant { bps: SLOW_BPS }),
        SLOW_LAT,
    )
}

/// The fabric of one arm: workers 1..n on healthy fast links, worker 0
/// attached per `mode`.
pub fn fabric_for(mode: PathMode, workers: usize) -> Fabric {
    let mut links = vec![fast_link(); workers];
    if mode == PathMode::SingleStable {
        links[0] = slow_link();
    }
    let mut fabric = Fabric::new(links);
    if mode == PathMode::Bonded {
        fabric.set_bond(0, Bond::new(vec![fast_link(), slow_link()]));
    }
    fabric
}

/// The scripted outage schedule for one arm: the fast path goes dark for
/// `outage_s` every [`CYCLE_S`], first at t = 20 s. Single-homed-fast arms
/// see it as a whole-link outage; the bonded arm as a path-0 outage (the
/// slow path survives); the stable arm never sees it at all.
pub fn outage_spec(
    mode: PathMode,
    outage_s: f64,
    horizon_s: f64,
) -> ChurnSpec {
    if outage_s <= 0.0 || mode == PathMode::SingleStable {
        return ChurnSpec::None;
    }
    let mut events = Vec::new();
    let mut t = 20.0;
    while t < horizon_s {
        events.push(TimedEvent {
            t,
            event: match mode {
                PathMode::SingleFast => {
                    ChurnEvent::LinkOutage { worker: 0, secs: outage_s }
                }
                PathMode::Bonded => ChurnEvent::PathOutage {
                    worker: 0,
                    path: 0,
                    secs: outage_s,
                },
                PathMode::SingleStable => unreachable!(),
            },
        });
        t += CYCLE_S;
    }
    ChurnSpec::Scripted { events }
}

/// Churn generation horizon for a run of `max_iters` iterations.
fn horizon_for(max_iters: usize) -> f64 {
    max_iters as f64 * PER_ITER_BOUND_S
}

/// The longest virtual-time gap between consecutive progress records
/// (from t = 0) — the stall headline: a single-homed arm riding out an
/// outage shows a gap near the outage length, a bonded arm only its
/// (degraded) per-iteration cost.
pub fn max_gap(res: &RunResult) -> f64 {
    let mut prev = 0.0;
    let mut gap: f64 = 0.0;
    for r in &res.records {
        gap = gap.max(r.time - prev);
        prev = r.time;
    }
    gap
}

/// One training run of `kind` with worker 0 attached per `mode`. `dim` is
/// exposed so the tests can shrink the oracle.
pub fn run_one(
    mode: PathMode,
    outage_s: f64,
    kind: StrategyKind,
    workers: usize,
    dim: usize,
    max_iters: usize,
    seed: u64,
) -> anyhow::Result<RunResult> {
    let spec = outage_spec(mode, outage_s, horizon_for(max_iters));
    let oracle = Quadratic::new(dim, workers, 0.5, 0.1, 0.3, 0.2, seed);
    let params = TrainParams {
        gamma: GAMMA,
        max_iters,
        log_every: 5,
        loss_target: Some(TARGET),
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        seed,
        fallback: DecoInput { s_g: S_G, a: FAST_BPS, b: FAST_LAT, t_comp: T_COMP },
        plan: PlanBasis::Bottleneck,
        // runs fan out run-level over the pool; each inner loop is serial
        threads: Some(1),
        churn: spec,
        ..Default::default()
    };
    let mut tl = TrainLoop::try_with_fabric(
        oracle,
        kind.build(),
        fabric_for(mode, workers),
        params,
    )?;
    Ok(tl.run("quadratic"))
}

/// The arm ladder. Labels are comma-free — they land in the CSV verbatim.
fn arms() -> Vec<(&'static str, PathMode, StrategyKind)> {
    vec![
        ("D-SGD (fast path)", PathMode::SingleFast, StrategyKind::DSgd),
        (
            "DeCo (fast path)",
            PathMode::SingleFast,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
        ),
        (
            "DeCo (stable path)",
            PathMode::SingleStable,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
        ),
        (
            "DeCo (bonded)",
            PathMode::Bonded,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
        ),
    ]
}

/// The full sweep: returns `(csv, table_rows)`. Deterministic in
/// `(scale, workers, dim, seed)` — the determinism contract
/// `tests/bond.rs` checks byte-for-byte.
pub fn sweep(
    scale: f64,
    workers: usize,
    dim: usize,
    seed: u64,
) -> anyhow::Result<(String, Vec<Vec<String>>)> {
    let max_iters = ((6000.0 * scale) as usize).max(50);
    let arms = arms();
    let scenarios: Vec<(String, f64)> = vec![
        ("calm".into(), 0.0),
        ("outage 45s".into(), 45.0),
    ];
    let n_combos = scenarios.len() * arms.len();
    let pool = WorkerPool::new(WorkerPool::default_threads().min(n_combos));
    eprintln!("[bonded] {n_combos} runs across {} threads", pool.threads());
    let results = pool.map(n_combos, |i| {
        let (_, outage_s) = &scenarios[i / arms.len()];
        let (_, mode, kind) = &arms[i % arms.len()];
        run_one(*mode, *outage_s, kind.clone(), workers, dim, max_iters, seed)
    });
    let mut results = results.into_iter();
    let mut csv = String::from(
        "scenario,outage_s,strategy,time_to_target,total_iters,max_gap_s\n",
    );
    let mut rows = Vec::new();
    for (label, outage_s) in &scenarios {
        let mut cells = vec![label.clone()];
        let mut times: Vec<Option<f64>> = Vec::new();
        for (arm, _, _) in &arms {
            let res = results.next().expect("one result per combo")?;
            let t = res.time_to_loss(TARGET);
            let gap = max_gap(&res);
            csv.push_str(&format!(
                "{label},{outage_s},{arm},{},{},{gap:.2}\n",
                t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                res.total_iters
            ));
            cells.push(
                t.map(|v| format!("{v:.1}s")).unwrap_or_else(|| "-".into()),
            );
            times.push(t);
        }
        // bonding's win over the best single path (either homing)
        let best_single = match (times[1], times[2]) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        cells.push(speedup(best_single, times[3]));
        rows.push(cells);
    }
    Ok((csv, rows))
}

pub fn main(scale: f64, workers: usize, seed: u64) -> anyhow::Result<()> {
    println!(
        "exp bonded — multi-path bonding vs single-homing under outage \
         churn on a {workers}-worker fabric\n(worker 0: fast \
         {:.0} Mbps/{FAST_LAT} s path with a {CYCLE_S:.0} s outage cycle + \
         stable {:.0} Mbps/{SLOW_LAT} s path; time-to-loss {TARGET} on the \
         quadratic; DeCo E = {UPDATE_EVERY})\n",
        FAST_BPS / 1e6,
        SLOW_BPS / 1e6
    );
    let (csv, rows) = sweep(scale, workers, 4096, seed)?;
    println!(
        "{}",
        format_table(
            &[
                "scenario",
                "D-SGD (fast)",
                "DeCo (fast)",
                "DeCo (stable)",
                "DeCo (bonded)",
                "vs best single",
            ],
            &rows
        )
    );
    let path = results_dir().join("bonded.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outage_spec_shapes() {
        // stable arm never sees churn; calm scenarios compile empty
        assert!(outage_spec(PathMode::SingleStable, 45.0, 1000.0).is_none());
        assert!(outage_spec(PathMode::Bonded, 0.0, 1000.0).is_none());
        // bonded events are path-scoped, single-fast events link-scoped,
        // and both compile against the matching fabric geometry
        let bonded = outage_spec(PathMode::Bonded, 45.0, 1000.0);
        let ChurnSpec::Scripted { events } = &bonded else {
            panic!("expected scripted")
        };
        assert_eq!(events.len(), 9, "1000 s horizon / 120 s cycle from 20 s");
        assert!(events.iter().all(|e| matches!(
            e.event,
            ChurnEvent::PathOutage { worker: 0, path: 0, .. }
        )));
        let fabric = fabric_for(PathMode::Bonded, 4);
        assert!(bonded.compile_for(4, &fabric.paths_per_worker()).is_ok());
        // ...but not against a single-path worker 0
        assert!(bonded.compile(4).is_err());
        let fast = outage_spec(PathMode::SingleFast, 45.0, 1000.0);
        let ChurnSpec::Scripted { events } = &fast else {
            panic!("expected scripted")
        };
        assert!(events.iter().all(|e| matches!(
            e.event,
            ChurnEvent::LinkOutage { worker: 0, .. }
        )));
        assert!(fast.compile(4).is_ok());
    }

    #[test]
    fn fabric_geometry_per_mode() {
        assert_eq!(
            fabric_for(PathMode::SingleFast, 4).paths_per_worker(),
            vec![1; 4]
        );
        let stable = fabric_for(PathMode::SingleStable, 4);
        assert_eq!(stable.link(0).bandwidth_at(0.0), SLOW_BPS);
        assert_eq!(stable.link(1).bandwidth_at(0.0), FAST_BPS);
        let bonded = fabric_for(PathMode::Bonded, 4);
        assert_eq!(bonded.paths_per_worker(), vec![2, 1, 1, 1]);
    }

    #[test]
    fn bonded_degrades_where_single_homing_stalls() {
        // the headline, small edition: under a 45 s fast-path outage the
        // single-homed-fast arm shows a progress gap near the outage
        // length, the bonded arm keeps making (degraded) progress, and
        // bonded reaches the target before either single-homed arm
        let kind = StrategyKind::DecoEvent { update_every: UPDATE_EVERY };
        let fast =
            run_one(PathMode::SingleFast, 45.0, kind.clone(), 4, 512, 3000, 7)
                .unwrap();
        let stable = run_one(
            PathMode::SingleStable,
            45.0,
            kind.clone(),
            4,
            512,
            3000,
            7,
        )
        .unwrap();
        let bonded =
            run_one(PathMode::Bonded, 45.0, kind, 4, 512, 3000, 7).unwrap();
        assert!(
            max_gap(&fast) >= 0.8 * 45.0,
            "single-homed fast should stall ~the outage: gap {:.1}s",
            max_gap(&fast)
        );
        assert!(
            max_gap(&bonded) < 15.0,
            "bonded should degrade, not stall: gap {:.1}s",
            max_gap(&bonded)
        );
        let tf = fast.time_to_loss(TARGET).expect("fast arm reaches");
        let ts = stable.time_to_loss(TARGET).expect("stable arm reaches");
        let tb = bonded.time_to_loss(TARGET).expect("bonded arm reaches");
        assert!(
            tb < tf.min(ts),
            "bonded {tb:.1}s should beat best single path \
             (fast {tf:.1}s, stable {ts:.1}s)"
        );
    }

    #[test]
    fn calm_bonded_beats_stable_single_homing() {
        // with no outages the bond still aggregates both paths, so it
        // out-runs the slow path alone
        let kind = StrategyKind::DecoEvent { update_every: UPDATE_EVERY };
        let stable = run_one(
            PathMode::SingleStable,
            0.0,
            kind.clone(),
            4,
            256,
            1500,
            7,
        )
        .unwrap();
        let bonded =
            run_one(PathMode::Bonded, 0.0, kind, 4, 256, 1500, 7).unwrap();
        let ts = stable.time_to_loss(TARGET).expect("stable reaches");
        let tb = bonded.time_to_loss(TARGET).expect("bonded reaches");
        assert!(tb < ts, "bonded {tb:.1}s vs stable-only {ts:.1}s");
    }
}
