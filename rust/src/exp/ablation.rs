//! Ablations on the design choices DESIGN.md calls out — all on the fast
//! quadratic testbed so they run in seconds:
//!
//! * `e_update`   — DeCo's refresh period E (Algorithm 2's sensitivity knob:
//!                  E=1 reacts instantly, E=∞ is CocktailSGD).
//! * `solver`     — Algorithm 1 vs the refined solver (interior φ minimum)
//!                  across network regimes: where does Remark 4's edge
//!                  choice lose?
//! * `compressor` — Top-k vs BlockTopK vs RandK vs Hybrid(RandK+Q8) under
//!                  identical (δ, τ): iteration quality of each operator.
//! * `wire`       — paper's δ·S_g accounting vs honest COO (64 bits/entry):
//!                  how much headline speed-up is accounting convention?
//! * `heterogeneity` — straggler fabric (the paper's deferred limitation):
//!                  DeCo planning on mean vs bottleneck (a, b).

use crate::compress::{
    BlockTopK, Compressor, HybridRandKQ8, RandK, TopK,
};
use crate::config::{wan_network, NetworkConfig};
use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::solve::{solve, solve_refined, DecoInput};
use crate::deco::DecoOutput;
use crate::exp::results_dir;
use crate::exp::runner::{ExpEnv, TaskSpec};
use crate::metrics::format_table;
use crate::netsim::Fabric;
use crate::optim::Quadratic;
use crate::strategy::StrategyKind;
use crate::util::Rng;

fn quad_task() -> TaskSpec {
    TaskSpec::quadratic()
}

/// E-sensitivity: DeCo update period under strongly varying bandwidth.
pub fn e_update(out_csv: &mut String) -> anyhow::Result<Vec<Vec<String>>> {
    let mut env = ExpEnv::new();
    env.verbose = false;
    let task = quad_task();
    let net = NetworkConfig::homogeneous(
        crate::netsim::TraceKind::Markov {
            levels_bps: vec![2e7, 1e8, 4e8],
            dwell_s: 25.0,
            seed: 5,
        },
        0.2,
    );
    let mut rows = Vec::new();
    for e in [1usize, 5, 20, 100, usize::MAX / 2] {
        let label = if e > 1_000_000 { "inf (Cocktail)".to_string() } else { e.to_string() };
        let kind = if e > 1_000_000 {
            StrategyKind::CocktailSgd
        } else {
            StrategyKind::DecoSgd { update_every: e }
        };
        let cfg = task.config(4, kind, net.clone(), 1.0);
        let res = env.run(&cfg)?;
        let t = res.time_to_loss(task.loss_target);
        out_csv.push_str(&format!(
            "e_update,{label},{}\n",
            t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into())
        ));
        rows.push(vec![
            "E".into(),
            label,
            t.map(|v| format!("{v:.1}s")).unwrap_or_else(|| "-".into()),
            format!("{}", res.total_iters),
        ]);
    }
    Ok(rows)
}

/// Algorithm 1 vs refined solver across regimes.
pub fn solver(out_csv: &mut String) -> Vec<Vec<String>> {
    let cases: &[(&str, DecoInput)] = &[
        ("gpt_wan", DecoInput { s_g: 124e6 * 32.0, a: 1e8, b: 0.1, t_comp: 0.35 }),
        ("vit_wan", DecoInput { s_g: 86e6 * 32.0, a: 5e8, b: 1.0, t_comp: 0.25 }),
        ("latency_dominated", DecoInput { s_g: 1e8, a: 1e9, b: 5.0, t_comp: 0.05 }),
        ("tiny_model_satellite", DecoInput { s_g: 1e7, a: 1e9, b: 2.0, t_comp: 0.02 }),
    ];
    let mut rows = Vec::new();
    for (name, inp) in cases {
        let a1 = solve(inp);
        let rf = solve_refined(inp);
        // both -inf (delta*=1 twice) => no compression needed, gain 1
        let gain = if a1.log_phi == rf.log_phi {
            1.0
        } else {
            (a1.log_phi - rf.log_phi).exp()
        };
        out_csv.push_str(&format!(
            "solver,{name},{},{:.4},{},{:.4},{gain:.3}\n",
            a1.tau, a1.delta, rf.tau, rf.delta
        ));
        let show = |o: &DecoOutput| format!("tau={} delta={:.4}", o.tau, o.delta);
        rows.push(vec![
            (*name).into(),
            show(&a1),
            show(&rf),
            format!("{gain:.2}x phi"),
        ]);
    }
    rows
}

/// Compressor quality at fixed (δ, τ): iterations to target on the
/// quadratic under each operator.
pub fn compressor(out_csv: &mut String) -> Vec<Vec<String>> {
    let (delta, tau, gamma) = (0.05, 2usize, 0.05f32);
    let comps: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("topk", Box::new(TopK::new(delta))),
        ("block_topk", Box::new(BlockTopK::new(delta))),
        ("randk", Box::new(RandK::new(delta))),
        ("hybrid_randk_q8", Box::new(HybridRandKQ8::new(delta))),
    ];
    let mut rows = Vec::new();
    for (name, comp) in comps {
        let oracle = Quadratic::new(1024, 4, 0.5, 0.1, 0.3, 1.0, 31);
        use crate::compress::ErrorFeedback;
        use crate::optim::GradOracle;
        use std::collections::VecDeque;
        let dim = oracle.dim();
        let n = oracle.workers();
        let f_star = oracle.f_star();
        let l0 = {
            let x = oracle.init();
            oracle.loss(&x)
        };
        let target = f_star + 0.1 * (l0 - f_star);
        let mut efs: Vec<ErrorFeedback> =
            (0..n).map(|_| ErrorFeedback::new(dim)).collect();
        let mut queues: Vec<VecDeque<Vec<f32>>> =
            (0..n).map(|_| VecDeque::new()).collect();
        let mut rng = Rng::new(0x5151);
        let mut x = oracle.init();
        let mut g = vec![0.0f32; dim];
        let mut iters_hit: Option<usize> = None;
        for t in 1..=8000usize {
            let mut agg = vec![0.0f32; dim];
            for w in 0..n {
                oracle.grad(w, t, &x, &mut g);
                queues[w].push_back(g.clone());
                if queues[w].len() > tau {
                    let mut old = queues[w].pop_front().unwrap();
                    efs[w].step(&mut old, comp.as_ref(), &mut rng);
                    for (a, v) in agg.iter_mut().zip(&old) {
                        *a += *v / n as f32;
                    }
                }
            }
            for (xi, ai) in x.iter_mut().zip(&agg) {
                *xi -= gamma * ai;
            }
            if t % 10 == 0 && oracle.loss(&x) <= target {
                iters_hit = Some(t);
                break;
            }
        }
        let shown = iters_hit
            .map(|i| i.to_string())
            .unwrap_or_else(|| ">8000".into());
        out_csv.push_str(&format!("compressor,{name},{shown}\n"));
        rows.push(vec![(*name).to_string(), shown]);
    }
    rows
}

/// Wire accounting: paper δ·S_g vs COO (values + u32 indices).
pub fn wire(out_csv: &mut String) -> anyhow::Result<Vec<Vec<String>>> {
    let task = quad_task();
    let net = wan_network(1e8, 0.2, 9);
    let mut rows = Vec::new();
    for (label, paper_wire) in [("paper delta*S_g", true), ("COO 64b/entry", false)] {
        let cfg = task.config(
            4,
            StrategyKind::DecoSgd { update_every: 20 },
            net.clone(),
            1.0,
        );
        let oracle = Quadratic::new(4096, 4, 0.5, 0.1, 0.3, 0.2, cfg.seed);
        let mut params: TrainParams = cfg.train_params(4096);
        params.paper_wire = paper_wire;
        let mut tl = TrainLoop::new(
            oracle,
            cfg.strategy.build(),
            cfg.network.link(),
            params,
        );
        let res = tl.run("quadratic");
        let t = res.time_to_loss(task.loss_target);
        out_csv.push_str(&format!(
            "wire,{label},{}\n",
            t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into())
        ));
        rows.push(vec![
            label.into(),
            t.map(|v| format!("{v:.1}s")).unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(rows)
}

/// Heterogeneity: straggler fabric, DeCo planning on the mean link vs the
/// bottleneck. This is the analytic (single-transfer) view; `exp hetero`
/// runs the full severity × strategy training sweep.
pub fn heterogeneity(out_csv: &mut String) -> Vec<Vec<String>> {
    use crate::netsim::BandwidthTrace;
    let n = 4;
    let s_g = 124e6 * 32.0;
    let bits = (0.05 * s_g) as u64;
    let mut rows = Vec::new();
    for (label, frac, mult) in [
        ("homogeneous", 1.0, 1.0),
        ("straggler 1/4 bw", 0.25, 1.0),
        ("straggler 1/4 bw + 2x lat", 0.25, 2.0),
    ] {
        let fabric = Fabric::with_straggler(
            n,
            BandwidthTrace::constant(1e8),
            0.1,
            frac,
            mult,
        );
        let healthy = fabric.link(1).arrival(0.0, bits);
        let sync = fabric.sync_arrival(0.0, bits);
        let (a_bot, b_bot) = fabric.bottleneck(0.0);
        let plan = solve(&DecoInput { s_g, a: a_bot, b: b_bot, t_comp: 0.35 });
        let (a_mean, b_mean) = fabric.mean(0.0);
        let blind =
            solve(&DecoInput { s_g, a: a_mean, b: b_mean, t_comp: 0.35 });
        out_csv.push_str(&format!(
            "heterogeneity,{label},{sync:.3},{healthy:.3},{},{:.4},{},{:.4}\n",
            plan.tau, plan.delta, blind.tau, blind.delta
        ));
        rows.push(vec![
            label.into(),
            format!("{sync:.2}s"),
            format!("{healthy:.2}s"),
            format!("tau={} delta={:.4}", plan.tau, plan.delta),
            format!("tau={} delta={:.4}", blind.tau, blind.delta),
        ]);
    }
    rows
}

pub fn main(which: &str) -> anyhow::Result<()> {
    let mut csv = String::from("ablation,case,values...\n");
    let run_all = which == "all";
    if run_all || which == "e_update" {
        println!("== ablation: DeCo refresh period E ==");
        println!(
            "{}",
            format_table(
                &["knob", "E", "time-to-target", "iters"],
                &e_update(&mut csv)?
            )
        );
    }
    if run_all || which == "solver" {
        println!("== ablation: Algorithm 1 vs refined solver ==");
        println!(
            "{}",
            format_table(
                &["regime", "Algorithm 1", "refined", "phi improvement"],
                &solver(&mut csv)
            )
        );
    }
    if run_all || which == "compressor" {
        println!("== ablation: compressor operator (delta=0.05, tau=2) ==");
        println!(
            "{}",
            format_table(&["compressor", "iters-to-target"], &compressor(&mut csv))
        );
    }
    if run_all || which == "wire" {
        println!("== ablation: wire accounting ==");
        println!(
            "{}",
            format_table(&["accounting", "time-to-target"], &wire(&mut csv)?)
        );
    }
    if run_all || which == "heterogeneity" {
        println!("== ablation: straggler fabric (paper's deferred limitation) ==");
        println!(
            "{}",
            format_table(
                &[
                    "fabric",
                    "sync arrival",
                    "healthy link",
                    "DeCo@bottleneck",
                    "DeCo@mean-link",
                ],
                &heterogeneity(&mut csv)
            )
        );
    }
    let path = results_dir().join("ablations.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn solver_ablation_finds_refinement_gains() {
        let mut csv = String::new();
        let rows = super::solver(&mut csv);
        assert_eq!(rows.len(), 4);
        // on the paper's operating points the two solvers agree (gain 1.0x)
        assert!(rows[0][3].starts_with("1.00x"));
    }

    #[test]
    fn heterogeneity_monotone() {
        let mut csv = String::new();
        let rows = super::heterogeneity(&mut csv);
        // sync arrival grows as the straggler worsens
        let t = |i: usize| {
            rows[i][1].trim_end_matches('s').parse::<f64>().unwrap()
        };
        assert!(t(1) > t(0));
        assert!(t(2) > t(1));
    }

    #[test]
    fn compressor_ablation_orders_sanely() {
        let mut csv = String::new();
        let rows = super::compressor(&mut csv);
        let iters = |name: &str| {
            rows.iter()
                .find(|r| r[0] == name)
                .and_then(|r| r[1].parse::<usize>().ok())
                .unwrap_or(usize::MAX)
        };
        // top-k must not be slower than rand-k (it keeps strictly more mass)
        assert!(iters("topk") <= iters("randk"));
        // block top-k close to global top-k
        let (t, b) = (iters("topk"), iters("block_topk"));
        assert!(b <= t.saturating_mul(3), "block {b} vs global {t}");
    }
}
