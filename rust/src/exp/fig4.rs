//! Fig. 4 — training time to target across the four model@dataset pairs for
//! the five methods (4 workers, WAN network). Prints the bar-chart data and
//! the D-SGD / CocktailSGD speed-ups the paper headlines.

use crate::config::wan_network;
use crate::exp::runner::{ExpEnv, TaskSpec};
use crate::exp::{results_dir, speedup};
use crate::metrics::format_table;

pub fn main(tasks: &[String], scale: f64, workers: usize) -> anyhow::Result<()> {
    let mut env = ExpEnv::new();
    let all = TaskSpec::paper_tasks();
    let selected: Vec<TaskSpec> = if tasks.is_empty() {
        all
    } else {
        all.into_iter()
            .filter(|t| tasks.iter().any(|n| n == t.name))
            .collect()
    };
    let mut rows = Vec::new();
    let mut csv = String::from(
        "task,method,time_to_target,total_iters,final_loss\n",
    );
    for task in &selected {
        // paper Sec. 5.2 network: ~200 ms latency, dynamic sub-Gbps
        // bandwidth drifting on tens of seconds (their Fig. 6 traces)
        let net = crate::config::NetworkConfig::homogeneous(
            crate::netsim::TraceKind::Markov {
                levels_bps: vec![8e7, 2e8, 4e8],
                dwell_s: 40.0,
                seed: 11,
            },
            0.2,
        );
        let _ = wan_network; // OU preset kept for the docs
        let results = env.sweep_strategies(task, workers, &net, scale)?;
        let time_of = |label: &str| {
            results
                .iter()
                .find(|(l, _)| *l == label)
                .and_then(|(_, r)| r.time_to_loss(task.loss_target))
        };
        let t_dsgd = time_of("D-SGD");
        let t_cocktail = time_of("CocktailSGD");
        let t_deco = time_of("DeCo-SGD");
        for (label, r) in &results {
            let t = r.time_to_loss(task.loss_target);
            csv.push_str(&format!(
                "{},{},{},{},{:.5}\n",
                task.name,
                label,
                t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                r.total_iters,
                r.final_loss()
            ));
            rows.push(vec![
                task.label.to_string(),
                label.to_string(),
                t.map(|v| format!("{v:.1}s")).unwrap_or_else(|| "-".into()),
                r.total_iters.to_string(),
                format!("{:.4}", r.final_loss()),
            ]);
        }
        rows.push(vec![
            task.label.to_string(),
            "speedup".into(),
            format!(
                "vs D-SGD {} | vs Cocktail {}",
                speedup(t_dsgd, t_deco),
                speedup(t_cocktail, t_deco)
            ),
            String::new(),
            String::new(),
        ]);
    }
    println!("Fig.4 — time-to-target, {workers} workers, WAN (0.2 Gbps OU, 200 ms)\n");
    println!(
        "{}",
        format_table(
            &["task", "method", "time-to-target", "iters", "final-loss"],
            &rows
        )
    );
    let path = results_dir().join("fig4_training_time.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}
