//! `repro exp scale` — the 100k-worker clock-engine campaign
//! (DESIGN.md §Perf, beyond the paper's n ≤ 32 testbed).
//!
//! Drives the shared-timeline-class `VirtualClock` across worker counts up
//! to 100 000 under three scenarios (uniform fabric, straggler, periodic
//! churn), one resumable campaign cell per (n, scenario) pair. Every cell
//! is a deterministic function of its id — the campaign CSV is
//! byte-identical whether the sweep ran straight through or was killed and
//! resumed (the CI exercises exactly that with `--max-cells`). Cells small
//! enough to afford it re-run under [`VirtualClock::with_reference_scan`]
//! — the O(n)-per-tick singleton-class engine — and assert bit-identical
//! sync arrivals, which is the in-campaign form of the property tests'
//! incremental-vs-reference contract.

use anyhow::Result;

use super::campaign::{run_campaign, CampaignOutcome, CampaignSpec};
use crate::coordinator::VirtualClock;
use crate::netsim::{BandwidthTrace, Fabric};
use crate::obs::{Attribution, PlanAudit};
use crate::timesim::{t_avg_closed_form, PipelineParams};

/// Reference-scan verification ceiling: above this the O(n·ticks)
/// singleton engine is the whole cost of the cell, so big cells trust the
/// property-tested engine (ref_checked = 0 in the CSV).
const REF_CHECK_MAX: usize = 1024;

const SCENARIOS: [&str; 3] = ["uniform", "straggler", "churn"];

fn fabric_for(scenario: &str, n: usize) -> Fabric {
    match scenario {
        "uniform" => {
            Fabric::homogeneous(n, BandwidthTrace::constant(1e8), 0.05)
        }
        "straggler" => Fabric::with_straggler(
            n,
            BandwidthTrace::constant(1e8),
            0.05,
            0.25,
            2.0,
        ),
        "churn" => {
            Fabric::homogeneous(n, BandwidthTrace::constant(1e8), 0.05)
        }
        other => unreachable!("unknown scenario {other}"),
    }
}

/// Per-iteration compute time of the synthetic schedule.
const T_COMP: f64 = 0.05;

/// Drive `clock` for `ticks` iterations of the scenario's deterministic
/// (τ, bits, mask) schedule and return the per-tick sync arrivals' last
/// value via the clock itself. With `attr`, each tick's fastest-worker
/// boundaries feed the streaming stall [`Attribution`] through its O(1)
/// flat path — the sweep stays O(classes) per tick. With `audit`, each
/// tick is priced against the closed-form prediction on the fabric's
/// t=0 bottleneck through the O(1) streaming [`PlanAudit`] fold (one
/// window per tick — the plan-bias columns of the campaign CSV).
fn drive(
    clock: &mut VirtualClock,
    scenario: &str,
    n: usize,
    ticks: usize,
    mut attr: Option<&mut Attribution>,
    mut audit: Option<&mut PlanAudit>,
) {
    let (a_bot, b_bot) = clock.fabric().bottleneck(0.0);
    // churn toggles the first n/16 workers every 17 ticks — one class
    // split on the first departure, stable class count afterwards
    let block = (n / 16).clamp(1, n - 1);
    let mut mask = vec![true; n];
    for k in 1..=ticks {
        if scenario == "churn" && k % 17 == 0 {
            let on = !mask[0];
            for m in mask.iter_mut().take(block) {
                *m = on;
            }
        }
        let tau = k % 4;
        let bits = 1_000_000 + (k as u64 % 7) * 250_000;
        let active = if scenario == "churn" { Some(&mask[..]) } else { None };
        if let Some(au) = audit.as_deref_mut() {
            let predicted = t_avg_closed_form(&PipelineParams {
                a: a_bot,
                b: b_bot,
                delta: 1.0,
                tau,
                t_comp: T_COMP,
                s_g: bits as f64,
            });
            au.replan(clock.now(), k, predicted, None);
        }
        let tick = clock.tick_members(T_COMP, tau, bits, active);
        if let Some(au) = audit.as_deref_mut() {
            au.tick(tick.tc);
        }
        if let Some(a) = attr.as_deref_mut() {
            if let Some(wt) = clock.fastest_last() {
                a.record_flat(
                    tick.ts,
                    T_COMP,
                    wt.tm,
                    wt.tc,
                    wt.tx_secs,
                    wt.retx_secs,
                    tick.tc,
                );
            }
        }
    }
}

/// One campaign cell: run the class engine, optionally cross-check the
/// reference engine bit-for-bit, and emit the CSV row.
fn run_cell(n: usize, scenario: &str, ticks: usize) -> Result<String> {
    let mut clock = VirtualClock::new(fabric_for(scenario, n));
    let mut attr = Attribution::new();
    let mut audit = PlanAudit::streaming();
    drive(&mut clock, scenario, n, ticks, Some(&mut attr), Some(&mut audit));
    audit.finish();
    let tx_sum: f64 = clock.tx_totals().iter().sum();
    let (now, classes) = (clock.now(), clock.timeline_classes());

    let ref_checked = n <= REF_CHECK_MAX;
    if ref_checked {
        let mut reference =
            VirtualClock::new(fabric_for(scenario, n)).with_reference_scan();
        drive(&mut reference, scenario, n, ticks, None, None);
        anyhow::ensure!(
            reference.now().to_bits() == now.to_bits(),
            "class engine diverged from the reference scan \
             (n={n} scenario={scenario}: {} vs {now})",
            reference.now()
        );
        let ref_tx: f64 = reference.tx_totals().iter().sum();
        anyhow::ensure!(
            ref_tx.to_bits() == tx_sum.to_bits(),
            "tx accounting diverged from the reference scan \
             (n={n} scenario={scenario}: {ref_tx} vs {tx_sum})"
        );
    }
    let plan = audit.summary();
    Ok(format!(
        "{n},{scenario},{ticks},{classes},{now:.6},{tx_sum:.6},{:.6},{:.6},\
         {:.6},{:.6},{:.6},{:.6},{}",
        attr.straggler_fraction(),
        attr.transfer_fraction(),
        attr.compute_fraction(),
        plan.mean_predicted(),
        plan.mean_realized(),
        plan.bias(),
        u8::from(ref_checked)
    ))
}

/// Run (or resume) the scale campaign. `--fast` shrinks the worker counts
/// for CI; `--dir` overrides the output directory; `--max-cells` pauses
/// after that many cells (the resume demonstration).
pub fn main(
    fast: bool,
    dir: Option<&str>,
    max_cells: Option<usize>,
) -> Result<()> {
    let (sizes, ticks): (&[usize], usize) = if fast {
        (&[64, 512, 4096], 200)
    } else {
        (&[1000, 10_000, 100_000], 400)
    };
    let dir = match dir {
        Some(d) => std::path::PathBuf::from(d),
        None => super::results_dir(),
    };
    let cells: Vec<String> = sizes
        .iter()
        .flat_map(|&n| {
            SCENARIOS.iter().map(move |s| format!("n{n}_{s}"))
        })
        .collect();
    let spec = CampaignSpec {
        dir,
        name: "scale".into(),
        fingerprint: format!(
            "scale-v3 sizes={sizes:?} ticks={ticks} scenarios={SCENARIOS:?}"
        ),
        header: "n,scenario,ticks,classes,virtual_time,tx_total,\
                 straggler_frac,transfer_frac,compute_frac,predicted_round,\
                 realized_round,plan_bias,ref_checked"
            .into(),
        cells,
        max_cells,
    };
    let csv_path = spec.csv_path();
    let outcome = run_campaign(&spec, |i, id| {
        let n = sizes[i / SCENARIOS.len()];
        let scenario = SCENARIOS[i % SCENARIOS.len()];
        debug_assert_eq!(id, format!("n{n}_{scenario}"));
        eprintln!("[scale] cell {id}: n={n} {scenario} ({ticks} ticks)");
        Ok(vec![run_cell(n, scenario, ticks)?])
    })?;
    match outcome {
        CampaignOutcome::Complete => {
            println!("{}", std::fs::read_to_string(&csv_path)?.trim_end());
            println!("wrote {}", csv_path.display());
        }
        CampaignOutcome::Paused { done, total } => {
            println!(
                "campaign paused at {done}/{total} cells (checkpointed to \
                 {}); rerun the same command to resume",
                spec.manifest_path().display()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_are_deterministic_and_ref_checked() {
        // n=96: big enough for real class sharing, small enough for the
        // in-cell reference cross-check to run (and it must pass)
        for scenario in SCENARIOS {
            let a = run_cell(96, scenario, 60).unwrap();
            let b = run_cell(96, scenario, 60).unwrap();
            assert_eq!(a, b, "{scenario} cell must be deterministic");
            assert!(a.ends_with(",1"), "{scenario} cell must be ref-checked");
        }
    }

    #[test]
    fn class_counts_stay_tiny_under_sharing() {
        let mut uniform = VirtualClock::new(fabric_for("uniform", 2048));
        drive(&mut uniform, "uniform", 2048, 50, None, None);
        assert_eq!(uniform.timeline_classes(), 1);

        let mut straggler = VirtualClock::new(fabric_for("straggler", 2048));
        drive(&mut straggler, "straggler", 2048, 50, None, None);
        assert_eq!(straggler.timeline_classes(), 2);

        let mut churn = VirtualClock::new(fabric_for("churn", 2048));
        drive(&mut churn, "churn", 2048, 50, None, None);
        // one split when the churn block first departs; stable afterwards
        assert_eq!(churn.timeline_classes(), 2);
    }

    #[test]
    fn audit_fold_realized_time_tracks_the_sweep_makespan() {
        for scenario in SCENARIOS {
            let mut clock = VirtualClock::new(fabric_for(scenario, 128));
            let mut audit = PlanAudit::streaming();
            drive(&mut clock, scenario, 128, 60, None, Some(&mut audit));
            audit.finish();
            let s = *audit.summary();
            // one window per tick, the first opening at t=0 — realized
            // time is exactly the sweep makespan
            assert_eq!((s.windows, s.iters), (60, 60));
            assert!(
                (s.real_time - clock.now()).abs() <= 1e-9 * clock.now(),
                "{scenario}: realized {} vs makespan {}",
                s.real_time,
                clock.now()
            );
            assert!(s.mean_predicted() > 0.0);
            assert!(s.mean_realized() > 0.0);
        }
    }

    #[test]
    fn attribution_fractions_partition_the_sweep_makespan() {
        for scenario in SCENARIOS {
            let mut clock = VirtualClock::new(fabric_for(scenario, 128));
            let mut attr = Attribution::new();
            drive(&mut clock, scenario, 128, 60, Some(&mut attr), None);
            assert_eq!(attr.ticks(), 60);
            assert!(attr.makespan() > 0.0);
            let gap = (attr.attributed() - attr.makespan()).abs();
            assert!(
                gap <= 1e-9 * attr.makespan(),
                "{scenario}: attributed {} vs makespan {}",
                attr.attributed(),
                attr.makespan()
            );
            let f = attr.straggler_fraction()
                + attr.transfer_fraction()
                + attr.compute_fraction();
            assert!((f - 1.0).abs() < 1e-9, "{scenario}: fractions sum {f}");
        }
    }
}
