//! Table 1 / Table 3 — training time (s) to the target metric under the
//! (a, b) grid {0.1, 0.5} Gbps × {0.1, 1.0} s for GPT and ViT, five
//! methods, plus the (τ*, δ*) DeCo chose (Table 3's extra columns).

use crate::config::NetworkConfig;
use crate::deco::{solve, DecoInput};
use crate::exp::runner::{ExpEnv, TaskSpec};
use crate::exp::{results_dir, speedup};
use crate::metrics::format_table;
use crate::netsim::TraceKind;

pub fn conditions() -> Vec<(f64, f64)> {
    vec![(0.1e9, 0.1), (0.5e9, 0.1), (0.1e9, 1.0), (0.5e9, 1.0)]
}

pub fn main(scale: f64, tasks: &[String]) -> anyhow::Result<()> {
    let mut env = ExpEnv::new();
    let all: Vec<TaskSpec> = ["gpt_wikitext", "vit_imagenet"]
        .iter()
        .filter_map(|n| TaskSpec::by_name(n))
        .filter(|t| tasks.is_empty() || tasks.iter().any(|n| n == t.name))
        .collect();
    let mut rows = Vec::new();
    let mut csv = String::from(
        "task,a_gbps,b_s,tau_star,delta_star,method,time_to_target\n",
    );
    for task in &all {
        for &(a, b) in &conditions() {
            // Table 1 uses *average* bandwidth a with slow dynamics
            let net = NetworkConfig::homogeneous(
                TraceKind::Markov {
                    levels_bps: vec![0.6 * a, a, 1.4 * a],
                    dwell_s: 40.0,
                    seed: 23,
                },
                b,
            );
            // What DeCo would pick under the nominal conditions (Table 3)
            let pick = solve(&DecoInput {
                s_g: task.s_g_bits,
                a,
                b,
                t_comp: task.t_comp,
            });
            let results = env.sweep_strategies(task, 4, &net, scale)?;
            let time_of = |label: &str| {
                results
                    .iter()
                    .find(|(l, _)| *l == label)
                    .and_then(|(_, r)| r.time_to_loss(task.loss_target))
            };
            let t_deco = time_of("DeCo-SGD");
            let mut cells = vec![
                task.label.to_string(),
                format!("{:.1}, {b:.1}", a / 1e9),
                format!("{}, {:.2}", pick.tau, pick.delta),
            ];
            for label in
                ["D-SGD", "Accordion", "DGA", "CocktailSGD", "DeCo-SGD"]
            {
                let t = time_of(label);
                csv.push_str(&format!(
                    "{},{},{},{},{:.4},{},{}\n",
                    task.name,
                    a / 1e9,
                    b,
                    pick.tau,
                    pick.delta,
                    label,
                    t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into())
                ));
                let su = if label != "DeCo-SGD" {
                    format!(" ({})", speedup(t, t_deco))
                } else {
                    String::new()
                };
                cells.push(
                    t.map(|v| format!("{v:.1}{su}"))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            rows.push(cells);
        }
    }
    println!("Table 1/3 — training time (s) to target; parenthesis = speedup of DeCo-SGD\n");
    println!(
        "{}",
        format_table(
            &[
                "task",
                "a(Gbps), b(s)",
                "tau*, delta*",
                "D-SGD",
                "Accordion",
                "DGA",
                "CocktailSGD",
                "DeCo-SGD"
            ],
            &rows
        )
    );
    let path = results_dir().join("table1_conditions.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deco_picks_match_table3_trends() {
        // Table 3: δ* grows with a; τ* grows with b
        let task = TaskSpec::by_name("gpt_wikitext").unwrap();
        let pick = |a: f64, b: f64| {
            solve(&DecoInput { s_g: task.s_g_bits, a, b, t_comp: task.t_comp })
        };
        let p11 = pick(0.1e9, 0.1);
        let p51 = pick(0.5e9, 0.1);
        let p110 = pick(0.1e9, 1.0);
        assert!(p51.delta > p11.delta, "delta* grows with bandwidth");
        assert!(p110.tau >= p11.tau, "tau* grows with latency");
        // paper's values: tau* in {2, 3}, delta* in {0.02, 0.10}
        assert!((1..=6).contains(&p11.tau));
        assert!(p11.delta < 0.2);
    }
}
