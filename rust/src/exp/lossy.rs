//! `exp lossy` — lossy transport study (beyond the paper: it assumes the
//! WAN delivers every gradient; real cross-region paths drop messages, and
//! retransmission turns a loss rate into a latency *tail*).
//!
//! Worker 0's WAN path drops messages; every drop costs a timeout plus an
//! exponentially backed-off retry, priced exactly through the prefix
//! integral (DESIGN.md §Robustness). The sweep crosses loss scenarios
//! (clean / i.i.d. / Gilbert–Elliott bursty) with three arms:
//!
//! * **D-SGD (wait-for-all)** / **DeCo (wait-for-all)** — every round
//!   completes at the *slowest* arrival, so one message riding a loss
//!   burst through the capped backoff ladder stalls the whole pipeline
//!   for the full retransmit tail;
//! * **DeCo (deadline)** — loss-aware DeCo: plans (τ, δ) against the
//!   retransmit-inflated bandwidth `a·(1−p̂)` and cuts each round at an
//!   adaptive quantile deadline; late gradients are absorbed next round
//!   (+1 staleness), never dropped.
//!
//! The headline is the `max_gap_s` column (longest virtual-time gap
//! between consecutive progress records): under bursty loss the
//! wait-for-all arms' gap grows to the burst dwell while the deadline
//! arm's stays near its per-round deadline — and on a clean fabric the
//! deadline arm is bit-identical to wait-for-all DeCo (the `p = 0`
//! contract `tests/properties.rs` checks at the engine level).
//!
//! Deterministic by construction: constant traces, pinned T_comp, the
//! analytic quadratic oracle, hash-seeded loss draws — the CI runs
//! `repro exp lossy --fast` twice and byte-compares the CSV.

use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::DecoInput;
use crate::exp::bonded::max_gap;
use crate::exp::{results_dir, speedup};
use crate::metrics::{format_table, RunResult};
use crate::netsim::{BandwidthTrace, Fabric, LossProcess};
use crate::optim::Quadratic;
use crate::strategy::{PlanBasis, StrategyKind};
use crate::util::WorkerPool;

/// Every link: healthy 100 Mbps / 50 ms — loss, not bandwidth, is the
/// variable under study.
const BPS: f64 = 1e8;
const LAT: f64 = 0.05;
/// Pinned per-iteration compute time (s).
const T_COMP: f64 = 0.2;
/// Pinned gradient size (bits): 0.2 s per full gradient, so one capped
/// 12-attempt backoff ladder (~15 s at RTO 0.1 s) dwarfs the clean round.
const S_G: f64 = 2e7;
const GAMMA: f32 = 0.02;
/// Same loss target as the quadratic TaskSpec.
const TARGET: f64 = 0.18;
/// DeCo refresh period (iterations). Long enough that the loss-rate EWMA
/// at each re-plan reflects the mixture, not the last burst.
const UPDATE_EVERY: usize = 75;
/// Deadline quantile: cover 90% of per-message retransmit ladders.
const QUANTILE: f64 = 0.9;
/// Retransmission timeout base (s) for every lossy scenario.
const RTO_S: f64 = 0.1;
/// Monitor smoothing: slow enough that one burst's attempt samples do not
/// swing the planned deadline.
const ALPHA: f64 = 0.1;
/// Seed of the loss draws (independent of the training seed).
const LOSS_SEED: u64 = 0x10557;
/// Bursty scenario: bad dwell cells of this many seconds...
const DWELL_S: f64 = 15.0;
/// ...hit with this stationary probability...
const PI_BAD: f64 = 0.1;
/// ...during which attempts are lost at `P_BAD` (calm cells: `P_GOOD`).
const P_BAD: f64 = 0.9;
const P_GOOD: f64 = 0.02;

/// The loss process worker 0's WAN path runs under, per scenario.
pub fn loss_for(scenario: &str) -> Option<LossProcess> {
    match scenario {
        "clean" => None,
        "iid 30%" => Some(LossProcess::iid(0.3, LOSS_SEED).with_rto(RTO_S)),
        "bursty" => Some(
            LossProcess::gilbert_elliott(
                P_GOOD, P_BAD, PI_BAD, DWELL_S, LOSS_SEED,
            )
            .with_rto(RTO_S),
        ),
        other => unreachable!("unknown scenario {other}"),
    }
}

const SCENARIOS: [&str; 3] = ["clean", "iid 30%", "bursty"];

/// The arm ladder. Labels are comma-free — they land in the CSV verbatim.
fn arms() -> Vec<(&'static str, StrategyKind)> {
    vec![
        ("D-SGD (wait-for-all)", StrategyKind::DSgd),
        (
            "DeCo (wait-for-all)",
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
        ),
        (
            "DeCo (deadline)",
            StrategyKind::DecoLossy {
                update_every: UPDATE_EVERY,
                quantile: QUANTILE,
            },
        ),
    ]
}

/// One training run of `kind` with worker 0 behind `loss`. `log_every` is
/// 1 so `max_gap` resolves individual stalled rounds, not 5-round windows.
pub fn run_one(
    loss: Option<&LossProcess>,
    kind: StrategyKind,
    workers: usize,
    dim: usize,
    max_iters: usize,
    seed: u64,
) -> anyhow::Result<RunResult> {
    let mut fabric =
        Fabric::homogeneous(workers, BandwidthTrace::constant(BPS), LAT);
    if let Some(proc) = loss {
        fabric.set_loss(0, proc.clone());
    }
    let oracle = Quadratic::new(dim, workers, 0.5, 0.1, 0.3, 0.2, seed);
    let params = TrainParams {
        gamma: GAMMA,
        max_iters,
        log_every: 1,
        loss_target: Some(TARGET),
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        monitor_alpha: ALPHA,
        seed,
        fallback: DecoInput { s_g: S_G, a: BPS, b: LAT, t_comp: T_COMP },
        plan: PlanBasis::Bottleneck,
        // runs fan out run-level over the pool; each inner loop is serial
        threads: Some(1),
        ..Default::default()
    };
    let mut tl = TrainLoop::try_with_fabric(oracle, kind.build(), fabric, params)?;
    Ok(tl.run("quadratic"))
}

/// The full sweep: returns `(csv, table_rows)`. Deterministic in
/// `(scale, workers, dim, seed)` — the CI byte-compares two `--fast` runs.
pub fn sweep(
    scale: f64,
    workers: usize,
    dim: usize,
    seed: u64,
) -> anyhow::Result<(String, Vec<Vec<String>>)> {
    let max_iters = ((4000.0 * scale) as usize).max(50);
    let arms = arms();
    let n_combos = SCENARIOS.len() * arms.len();
    let pool = WorkerPool::new(WorkerPool::default_threads().min(n_combos));
    eprintln!("[lossy] {n_combos} runs across {} threads", pool.threads());
    let results = pool.map(n_combos, |i| {
        let loss = loss_for(SCENARIOS[i / arms.len()]);
        let (_, kind) = &arms[i % arms.len()];
        run_one(loss.as_ref(), kind.clone(), workers, dim, max_iters, seed)
    });
    let mut results = results.into_iter();
    let mut csv = String::from(
        "scenario,strategy,time_to_target,total_iters,max_gap_s,mean_loss\n",
    );
    let mut rows = Vec::new();
    for scenario in SCENARIOS {
        let mut cells = vec![scenario.to_string()];
        let mut times: Vec<Option<f64>> = Vec::new();
        for (arm, _) in &arms {
            let res = results.next().expect("one result per combo")?;
            let t = res.time_to_loss(TARGET);
            let gap = max_gap(&res);
            // realized mean loss rate of the scenario process over this
            // run's span — the CSV-level predicted-vs-realized anchor
            let mean_loss = loss_for(scenario)
                .map(|p| p.mean_rate_over(0, 0.0, res.total_time))
                .unwrap_or(0.0);
            csv.push_str(&format!(
                "{scenario},{arm},{},{},{gap:.2},{mean_loss:.4}\n",
                t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                res.total_iters
            ));
            cells.push(
                t.map(|v| format!("{v:.1}s ({gap:.1}s gap)"))
                    .unwrap_or_else(|| format!("- ({gap:.1}s gap)")),
            );
            times.push(t);
        }
        // the deadline arm's win over wait-for-all DeCo
        cells.push(speedup(times[1], times[2]));
        rows.push(cells);
    }
    Ok((csv, rows))
}

pub fn main(
    scale: f64,
    workers: usize,
    seed: u64,
    fast: bool,
) -> anyhow::Result<()> {
    let (dim, scale) = if fast { (256, scale.min(0.05)) } else { (4096, scale) };
    println!(
        "exp lossy — message loss × retransmission on a {workers}-worker \
         fabric\n(worker 0's WAN drops messages: i.i.d. vs Gilbert–Elliott \
         {DWELL_S:.0} s dwells at p_bad = {P_BAD}; RTO {RTO_S} s doubling; \
         time-to-loss {TARGET} on the quadratic; DeCo E = {UPDATE_EVERY}, \
         deadline quantile {QUANTILE})\n",
    );
    let (csv, rows) = sweep(scale, workers, dim, seed)?;
    println!(
        "{}",
        format_table(
            &[
                "scenario",
                "D-SGD (wait-for-all)",
                "DeCo (wait-for-all)",
                "DeCo (deadline)",
                "vs wait-for-all",
            ],
            &rows
        )
    );
    let path = results_dir().join("lossy.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_processes_shape() {
        assert!(loss_for("clean").is_none());
        let iid = loss_for("iid 30%").unwrap();
        assert!(!iid.is_lossless());
        assert_eq!(iid.rto_s(), RTO_S);
        let bursty = loss_for("bursty").unwrap();
        assert!(!bursty.is_lossless());
        // the bursty process really mixes both dwell states over a long
        // horizon: mean rate strictly between p_good and p_bad
        let mean = bursty.mean_rate_over(0, 0.0, 10_000.0);
        assert!(
            mean > P_GOOD && mean < P_BAD,
            "bursty mean rate {mean} outside ({P_GOOD}, {P_BAD})"
        );
    }

    #[test]
    fn clean_deadline_deco_is_bit_identical_to_wait_for_all() {
        // the p = 0 contract at experiment level: with no loss process the
        // deadline arm plans no deadline and replays wait-for-all DeCo
        // bit-for-bit
        let wfa = run_one(
            None,
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
            4,
            256,
            400,
            7,
        )
        .unwrap();
        let dl = run_one(
            None,
            StrategyKind::DecoLossy {
                update_every: UPDATE_EVERY,
                quantile: QUANTILE,
            },
            4,
            256,
            400,
            7,
        )
        .unwrap();
        assert_eq!(wfa.total_iters, dl.total_iters);
        assert_eq!(wfa.total_time.to_bits(), dl.total_time.to_bits());
        assert_eq!(wfa.records.len(), dl.records.len());
        for (a, b) in wfa.records.iter().zip(&dl.records) {
            assert_eq!(a.time.to_bits(), b.time.to_bits());
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        }
    }

    #[test]
    fn deadline_deco_bounds_the_gap_under_bursty_loss() {
        // the headline, small edition: under Gilbert–Elliott bursts the
        // wait-for-all arms ride the full retransmit ladder of every bad
        // dwell (gap ~ the 15 s dwell), the deadline arm cuts each round
        // at its planned quantile deadline and absorbs the late gradient
        // next round
        let bursty = loss_for("bursty").unwrap();
        let dsgd = run_one(
            Some(&bursty),
            StrategyKind::DSgd,
            4,
            512,
            3000,
            7,
        )
        .unwrap();
        let wfa = run_one(
            Some(&bursty),
            StrategyKind::DecoEvent { update_every: UPDATE_EVERY },
            4,
            512,
            3000,
            7,
        )
        .unwrap();
        let dl = run_one(
            Some(&bursty),
            StrategyKind::DecoLossy {
                update_every: UPDATE_EVERY,
                quantile: QUANTILE,
            },
            4,
            512,
            3000,
            7,
        )
        .unwrap();
        assert!(
            max_gap(&dsgd) > 10.0,
            "wait-for-all D-SGD should stall on the retransmit tail: \
             gap {:.1}s",
            max_gap(&dsgd)
        );
        assert!(
            max_gap(&wfa) > 10.0,
            "wait-for-all DeCo should stall on the retransmit tail: \
             gap {:.1}s",
            max_gap(&wfa)
        );
        assert!(
            max_gap(&dl) < 8.0,
            "deadline DeCo should cut, not stall: gap {:.1}s",
            max_gap(&dl)
        );
        assert!(
            max_gap(&dl) < 0.6 * max_gap(&wfa).min(max_gap(&dsgd)),
            "deadline gap {:.1}s vs wait-for-all {:.1}s / {:.1}s",
            max_gap(&dl),
            max_gap(&wfa),
            max_gap(&dsgd)
        );
        // staleness absorption must not cost convergence on the quadratic
        assert!(
            dl.time_to_loss(TARGET).is_some(),
            "deadline arm should still reach the target \
             (final loss {:.3})",
            dl.final_loss()
        );
    }
}
