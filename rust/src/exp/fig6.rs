//! Fig. 6 (appendix C.3) — the bandwidth trace and DeCo's adaptive δ(t)
//! under a fixed 200 ms latency: one DeCo-SGD run per task, logging
//! (virtual time, monitored bandwidth, chosen δ, τ).

use crate::config::wan_network;
use crate::exp::runner::{ExpEnv, TaskSpec};
use crate::exp::results_dir;
use crate::strategy::StrategyKind;

pub fn main(task_name: &str, scale: f64) -> anyhow::Result<()> {
    let task = TaskSpec::by_name(task_name)
        .or_else(|| (task_name == "quadratic").then(TaskSpec::quadratic))
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?;
    let mut env = ExpEnv::new();
    // strongly varying bandwidth so the adaptation is visible
    let net = crate::config::NetworkConfig::homogeneous(
        crate::netsim::TraceKind::Markov {
            levels_bps: vec![4e7, 1e8, 2.5e8],
            dwell_s: 30.0,
            seed: 17,
        },
        0.2,
    );
    let _ = wan_network(1e8, 0.2, 0); // (kept for docs symmetry)
    let cfg = task.config(
        4,
        StrategyKind::DecoSgd { update_every: 10 },
        net,
        scale,
    );
    let mut cfg = cfg;
    cfg.stop.loss_target = None; // run the full horizon to see adaptation
    cfg.log_every = 2;
    let res = env.run(&cfg)?;
    println!(
        "Fig.6 — DeCo-SGD adaptation on {} (Markov bandwidth, b=200 ms)\n",
        task.label
    );
    println!(
        "{:>8} {:>10} {:>12} {:>7} {:>7}",
        "iter", "vtime(s)", "bw_est(Mbps)", "delta", "tau"
    );
    let mut csv = String::from("iter,time,bandwidth_bps,delta,tau,loss\n");
    for r in &res.records {
        println!(
            "{:>8} {:>10.1} {:>12.1} {:>7.3} {:>7}",
            r.iter,
            r.time,
            r.bandwidth / 1e6,
            r.delta,
            r.tau
        );
        csv.push_str(&format!(
            "{},{:.3},{:.0},{},{},{:.5}\n",
            r.iter, r.time, r.bandwidth, r.delta, r.tau, r.loss
        ));
    }
    let path = results_dir().join(format!("fig6_adaptive_{}.csv", task.name));
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    // adaptation summary
    let deltas: Vec<f64> = res.records.iter().map(|r| r.delta).collect();
    let dmin = deltas.iter().cloned().fold(f64::INFINITY, f64::min);
    let dmax = deltas.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "\ndelta ranged {dmin:.3} .. {dmax:.3} — {} distinct values",
        {
            let mut ds = deltas.clone();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ds.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
            ds.len()
        }
    );
    Ok(())
}
