//! Experiment generators — one per table/figure in the paper (see DESIGN.md
//! §4 for the index). Each prints the same rows/series the paper reports and
//! writes CSV next to it under `results/`.
//!
//! | id     | paper artifact                                  |
//! |--------|--------------------------------------------------|
//! | fig1   | D-SGD throughput-efficiency heatmap              |
//! | fig2   | running timelines of D-SGD variants              |
//! | fig4   | time-to-target across model@dataset pairs        |
//! | fig5   | scalability n = 4..32 (also appendix Fig. 7/8)   |
//! | fig6   | bandwidth trace + adaptive δ(t) (appendix C.3)   |
//! | table1 | training time under (a, b) grid (also Table 3)   |
//! | thm3   | validation: closed form vs event recurrence      |
//! | phi    | validation: iterations-to-ε ordering follows φ   |
//! | hetero | straggler severity × strategy on a per-worker    |
//! |        | fabric: bottleneck vs mean-link DeCo planning    |
//! |        | (beyond the paper — its deferred limitation)     |
//! | churn  | worker churn × link outages on the elastic       |
//! |        | fabric: event-triggered vs boundary-only DeCo    |
//! |        | re-planning (beyond the paper)                   |
//! | topo   | region count × WAN:LAN ratio on the hierarchical |
//! |        | multi-datacenter topology: two-tier DeCo vs the  |
//! |        | flat shared-egress star (beyond the paper)       |
//! | bonded | multi-path bonding vs single-homing under outage |
//! |        | churn: water-filling failover degrades where a   |
//! |        | single path stalls (beyond the paper)            |
//! | scale  | 100k-worker clock-engine campaign: shared        |
//! |        | timeline classes vs the O(n) reference scan,     |
//! |        | resumable via the campaign manifest (beyond the  |
//! |        | paper)                                           |
//! | lossy  | message loss × retransmission: deadline-bounded  |
//! |        | partial aggregation vs wait-for-all under bursty |
//! |        | Gilbert–Elliott drops (beyond the paper)         |

pub mod ablation;
pub mod bonded;
pub mod campaign;
pub mod churn;
pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod hetero;
pub mod lossy;
pub mod phi;
pub mod runner;
pub mod scale;
pub mod table1;
pub mod thm3;
pub mod topo;

pub use runner::{ExpEnv, TaskSpec};

use std::path::PathBuf;

/// Where experiment CSVs land.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Shared speed-ups formatting: baseline_time / method_time.
pub fn speedup(baseline: Option<f64>, method: Option<f64>) -> String {
    match (baseline, method) {
        (Some(b), Some(m)) if m > 0.0 => format!("{:.2}x", b / m),
        _ => "-".into(),
    }
}
