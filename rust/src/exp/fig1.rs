//! Fig. 1 — heatmap of D-SGD throughput efficiency (%) over (latency,
//! bandwidth), 4 nodes training GPT-2. Efficiency = throughput at (x, y)
//! divided by the compute-bound maximum, i.e. `T_comp / T_avg` of plain
//! D-SGD. Regenerated from the Theorem-3 model (the paper measured it; the
//! model's validity is established by `exp thm3`).

use crate::exp::results_dir;
use crate::timesim::model::dsgd_throughput_efficiency;

pub struct Fig1Out {
    pub latencies_s: Vec<f64>,
    pub bandwidths_bps: Vec<f64>,
    /// efficiency[lat][bw] in [0, 1]
    pub efficiency: Vec<Vec<f64>>,
}

pub fn run(t_comp: f64, s_g_bits: f64) -> Fig1Out {
    // paper's axes: latency 0–1000 ms, bandwidth ~0.1–10 Gbps
    let latencies_s: Vec<f64> =
        [0.0, 0.05, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0].to_vec();
    let bandwidths_bps: Vec<f64> = [
        0.1e9, 0.2e9, 0.5e9, 1e9, 2e9, 4e9, 6e9, 8e9, 10e9,
    ]
    .to_vec();
    let efficiency = latencies_s
        .iter()
        .map(|&b| {
            bandwidths_bps
                .iter()
                .map(|&a| dsgd_throughput_efficiency(a, b, t_comp, s_g_bits))
                .collect()
        })
        .collect();
    Fig1Out { latencies_s, bandwidths_bps, efficiency }
}

pub fn main(t_comp: f64) -> anyhow::Result<()> {
    let s_g = 124e6 * 32.0; // GPT-2 124M f32 gradients
    let out = run(t_comp, s_g);
    println!(
        "Fig.1 — D-SGD throughput efficiency (%), GPT-2 124M, T_comp={t_comp}s"
    );
    print!("{:>9} |", "lat\\bw");
    for a in &out.bandwidths_bps {
        print!("{:>7.1}G", a / 1e9);
    }
    println!();
    println!("{}", "-".repeat(11 + 8 * out.bandwidths_bps.len()));
    let mut csv = String::from("latency_s,bandwidth_bps,efficiency\n");
    for (i, b) in out.latencies_s.iter().enumerate() {
        print!("{:>8.2}s |", b);
        for (j, a) in out.bandwidths_bps.iter().enumerate() {
            let e = out.efficiency[i][j];
            print!("{:>7.1}%", e * 100.0);
            csv.push_str(&format!("{b},{a},{e:.6}\n"));
        }
        println!();
    }
    let path = results_dir().join("fig1_heatmap.csv");
    std::fs::write(&path, csv)?;
    println!("\nwrote {path:?}");
    println!(
        "paper check: efficiency <= ~50% below 2 Gbps at 200 ms -> {:.1}%",
        run(t_comp, s_g).efficiency[3][4] * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heatmap_shape_matches_paper() {
        let out = run(2.0, 124e6 * 32.0);
        // efficiency decreases with latency (rows) and increases with
        // bandwidth (cols)
        for j in 0..out.bandwidths_bps.len() {
            for i in 1..out.latencies_s.len() {
                assert!(out.efficiency[i][j] <= out.efficiency[i - 1][j] + 1e-12);
            }
        }
        for i in 0..out.latencies_s.len() {
            for j in 1..out.bandwidths_bps.len() {
                assert!(out.efficiency[i][j] >= out.efficiency[i][j - 1] - 1e-12);
            }
        }
        // paper's headline: the ~50% contour passes through
        // (2 Gbps, 200 ms)
        let i200 = out.latencies_s.iter().position(|&b| b == 0.2).unwrap();
        let j2g = out.bandwidths_bps.iter().position(|&a| a == 2e9).unwrap();
        let mid = out.efficiency[i200][j2g];
        assert!((0.35..=0.65).contains(&mid), "mid={mid}");
        // best corner far better than worst corner
        let best = out.efficiency[0][out.bandwidths_bps.len() - 1];
        let worst = out.efficiency[out.latencies_s.len() - 1][0];
        assert!(best > 0.75, "best={best}");
        assert!(worst < 0.2, "worst={worst}");
        assert!(best > 3.0 * worst);
    }
}
