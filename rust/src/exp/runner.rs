//! Shared experiment runner: task specs (the paper's four model@dataset
//! pairs with paper-scale time pinning), oracle construction (PJRT or
//! analytic), and the strategy-sweep helper every figure uses.

use crate::config::{ExperimentConfig, NetworkConfig, StopConfig};
use crate::coordinator::TrainLoop;
use crate::metrics::sink::BufferSink;
use crate::metrics::RunResult;
use crate::netsim::Fabric;
use crate::obs::{BufferTracer, TraceEvent};
use crate::optim::{GradOracle, Logistic, Quadratic};
use crate::runtime::{PjrtOracle, Runtime};
use crate::strategy::StrategyKind;
use crate::topo::Topology;
use crate::util::WorkerPool;
use anyhow::{anyhow, Result};

/// A benchmark task: the model, its loss target, and the *paper-scale*
/// pinned time parameters (`t_comp`, `S_g`) so the virtual clock prices
/// iterations like the paper's testbed even though the proxy model is small
/// (DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    /// manifest model name, or "quadratic"/"logistic"
    pub model: &'static str,
    pub label: &'static str,
    pub gamma: f32,
    pub loss_target: f64,
    pub t_comp: f64,
    pub s_g_bits: f64,
    pub max_iters: usize,
    pub clip_norm: Option<f64>,
}

impl TaskSpec {
    /// The paper's four evaluation pairs (Sec. 5.1). Gradient sizes use the
    /// paper's true model scales (GPT-2 124M, ViT-Base 86M, the small CNN);
    /// compute times approximate the A40 testbed per-iteration cost.
    pub fn paper_tasks() -> Vec<TaskSpec> {
        vec![
            TaskSpec {
                name: "cnn_fmnist",
                model: "cnn_fmnist",
                label: "CNN@FMNIST",
                gamma: 0.03,
                loss_target: 0.35,
                t_comp: 0.1,
                s_g_bits: 208_000.0 * 32.0,
                max_iters: 400,
                clip_norm: Some(5.0),
            },
            TaskSpec {
                name: "cnn_cifar",
                model: "cnn_cifar",
                label: "CNN@CIFAR-10",
                gamma: 0.03,
                loss_target: 0.5,
                t_comp: 0.1,
                s_g_bits: 270_000.0 * 32.0,
                max_iters: 400,
                clip_norm: Some(5.0),
            },
            TaskSpec {
                name: "vit_imagenet",
                model: "vit_tiny",
                label: "ViT@ImageNet",
                gamma: 0.15,
                loss_target: 0.12,
                t_comp: 0.25,
                s_g_bits: 86e6 * 32.0,
                max_iters: 300,
                clip_norm: Some(5.0),
            },
            TaskSpec {
                name: "gpt_wikitext",
                model: "gpt_mini",
                label: "GPT@Wikitext",
                gamma: 0.3,
                loss_target: 3.85, // ppl ≈ 47 on the synthetic corpus
                t_comp: 0.35,
                s_g_bits: 124e6 * 32.0,
                max_iters: 350,
                clip_norm: Some(2.0),
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<TaskSpec> {
        Self::paper_tasks().into_iter().find(|t| t.name == name)
    }

    /// Cheap analytic stand-in used by `--fast` smoke runs and unit tests.
    /// γ sits inside Theorem 1's stability region for DeCo-scale (δ, τ).
    pub fn quadratic() -> TaskSpec {
        TaskSpec {
            name: "quadratic",
            model: "quadratic",
            label: "Quadratic",
            gamma: 0.02,
            loss_target: 0.18,
            t_comp: 0.2,
            s_g_bits: 124e6 * 32.0,
            max_iters: 6000,
            clip_norm: None,
        }
    }

    pub fn config(
        &self,
        workers: usize,
        strategy: StrategyKind,
        network: NetworkConfig,
        scale: f64,
    ) -> ExperimentConfig {
        ExperimentConfig {
            task: self.model.to_string(),
            workers,
            gamma: self.gamma,
            strategy,
            network,
            stop: StopConfig {
                max_iters: ((self.max_iters as f64 * scale) as usize).max(20),
                loss_target: Some(self.loss_target),
                max_virtual_time: None,
            },
            seed: 7,
            t_comp: Some(self.t_comp),
            s_g_bits: Some(self.s_g_bits),
            log_every: 5,
            block_topk: false,
            clip_norm: self.clip_norm,
            churn: crate::elastic::ChurnSpec::None,
            drain: crate::elastic::DrainPolicy::Drop,
        }
    }
}

/// Experiment environment: lazily-initialized PJRT runtime shared by all
/// runs in one process (each run still compiles its own executable — PJRT
/// executables are single-threaded-owned here).
pub struct ExpEnv {
    runtime: Option<Runtime>,
    pub verbose: bool,
}

impl ExpEnv {
    pub fn new() -> Self {
        Self { runtime: None, verbose: true }
    }

    fn runtime(&mut self) -> Result<&Runtime> {
        if self.runtime.is_none() {
            let dir = crate::runtime::default_artifacts_dir();
            self.runtime = Some(Runtime::load(dir)?);
        }
        Ok(self.runtime.as_ref().unwrap())
    }

    /// Execute one configured run.
    pub fn run(&mut self, cfg: &ExperimentConfig) -> Result<RunResult> {
        if self.verbose {
            eprintln!(
                "[run] task={} strategy={} n={} ...",
                cfg.task,
                cfg.strategy.label(),
                cfg.workers
            );
        }
        let res = match cfg.task.as_str() {
            "quadratic" | "logistic" => Self::run_analytic(cfg, None),
            model => {
                let rt = self.runtime()?;
                let exec = rt.grad_exec(model)?;
                let oracle = PjrtOracle::new(exec, cfg.workers, cfg.seed)
                    .with_eval_batches(6);
                // PJRT executables are single-threaded-owned: pin the loop
                // to a serial pool so the worker phase never calls the
                // executable concurrently
                Self::run_with(oracle, cfg, Some(1))
            }
        };
        if self.verbose {
            if let Ok(r) = &res {
                eprintln!(
                    "[run]   -> iters={} vtime={:.1}s loss={:.4}",
                    r.total_iters,
                    r.total_time,
                    r.final_loss()
                );
            }
        }
        res
    }

    /// The analytic tasks, runnable without `&self` (no PJRT runtime) —
    /// which is what lets whole strategy sweeps move onto the pool.
    /// `threads` sizes the inner training loop's pool.
    fn run_analytic(
        cfg: &ExperimentConfig,
        threads: Option<usize>,
    ) -> Result<RunResult> {
        let fabric = cfg.network.build_fabric(cfg.workers)?;
        let topology = cfg.network.build_topology(cfg.workers, &fabric)?;
        Self::run_analytic_on(cfg, fabric, topology, threads)
    }

    /// Analytic run on a prebuilt fabric/topology. Sweeps construct the
    /// network **once per link spec** and hand each cell a clone:
    /// stochastic trace grids and their prefix integrals are `Arc`-shared,
    /// so cloning a fabric is O(links) and never regenerates an OU/Markov
    /// sample path — the per-cell trace rebuild the serial sweeps paid.
    fn run_analytic_on(
        cfg: &ExperimentConfig,
        fabric: Fabric,
        topology: Topology,
        threads: Option<usize>,
    ) -> Result<RunResult> {
        match cfg.task.as_str() {
            "quadratic" => Self::run_prebuilt(
                Quadratic::new(4096, cfg.workers, 0.5, 0.1, 0.3, 0.2, cfg.seed),
                cfg,
                fabric,
                topology,
                threads,
            ),
            "logistic" => Self::run_prebuilt(
                Logistic::new(512, cfg.workers, 400, 32, 1e-4, 1.0, cfg.seed),
                cfg,
                fabric,
                topology,
                threads,
            ),
            other => Err(anyhow!("task '{other}' has no analytic oracle")),
        }
    }

    /// One analytic run with the observability tracer attached: returns
    /// the training result plus the buffered virtual-time trace events
    /// (DESIGN.md §Observability). Deliberately restricted to the
    /// analytic oracles — `repro trace` is a determinism surface, so it
    /// never touches the PJRT runtime.
    pub fn run_traced(
        cfg: &ExperimentConfig,
    ) -> Result<(RunResult, Vec<TraceEvent>)> {
        let fabric = cfg.network.build_fabric(cfg.workers)?;
        let topology = cfg.network.build_topology(cfg.workers, &fabric)?;
        match cfg.task.as_str() {
            "quadratic" => Self::run_prebuilt_traced(
                Quadratic::new(4096, cfg.workers, 0.5, 0.1, 0.3, 0.2, cfg.seed),
                cfg,
                fabric,
                topology,
            ),
            "logistic" => Self::run_prebuilt_traced(
                Logistic::new(512, cfg.workers, 400, 32, 1e-4, 1.0, cfg.seed),
                cfg,
                fabric,
                topology,
            ),
            other => Err(anyhow!("task '{other}' has no analytic oracle")),
        }
    }

    fn run_prebuilt_traced<O: GradOracle>(
        oracle: O,
        cfg: &ExperimentConfig,
        fabric: Fabric,
        topology: Topology,
    ) -> Result<(RunResult, Vec<TraceEvent>)> {
        let dim = oracle.dim();
        let params = cfg.train_params(dim);
        let mut tl = TrainLoop::try_with_topology(
            oracle,
            cfg.strategy.build(),
            fabric,
            topology,
            params,
        )?;
        let mut sink = BufferSink::new();
        let mut tracer = BufferTracer::new();
        let mut result = tl.run_traced(&cfg.task, &mut sink, &mut tracer)?;
        result.records = sink.into_records();
        Ok((result, tracer.into_events()))
    }

    fn run_with<O: GradOracle>(
        oracle: O,
        cfg: &ExperimentConfig,
        threads: Option<usize>,
    ) -> Result<RunResult> {
        // every run is priced on a per-worker fabric; the homogeneous spec
        // replicates the base link and stays bit-identical to the former
        // single shared link (tests/fabric.rs). The aggregation tree comes
        // from the topology spec — flat unless configured — and
        // try_with_topology surfaces invalid config-driven churn or
        // topology specs as errors, not panics.
        let fabric = cfg.network.build_fabric(cfg.workers)?;
        let topology = cfg.network.build_topology(cfg.workers, &fabric)?;
        Self::run_prebuilt(oracle, cfg, fabric, topology, threads)
    }

    fn run_prebuilt<O: GradOracle>(
        oracle: O,
        cfg: &ExperimentConfig,
        fabric: Fabric,
        topology: Topology,
        threads: Option<usize>,
    ) -> Result<RunResult> {
        let dim = oracle.dim();
        let mut params = cfg.train_params(dim);
        if threads.is_some() {
            params.threads = threads;
        }
        let mut tl = TrainLoop::try_with_topology(
            oracle,
            cfg.strategy.build(),
            fabric,
            topology,
            params,
        )?;
        Ok(tl.run(&cfg.task))
    }

    /// Run the paper's five-method sweep on one task/network; returns
    /// (label, result) pairs in paper order.
    ///
    /// Analytic tasks run the five independent `TrainLoop`s concurrently on
    /// the pool (one run per thread, each loop internally serial — run-level
    /// parallelism beats iteration-level here and avoids oversubscription),
    /// so a whole figure's sweep costs one slowest-run wall-clock. PJRT
    /// tasks fall back to the serial path: executables are
    /// single-threaded-owned.
    pub fn sweep_strategies(
        &mut self,
        task: &TaskSpec,
        workers: usize,
        network: &NetworkConfig,
        scale: f64,
    ) -> Result<Vec<(&'static str, RunResult)>> {
        let kinds = StrategyKind::paper_baselines();
        let analytic = matches!(task.model, "quadratic" | "logistic");
        let pool = WorkerPool::new(
            WorkerPool::default_threads().min(kinds.len()),
        );
        if analytic && pool.threads() > 1 {
            if self.verbose {
                eprintln!(
                    "[sweep] task={} — {} strategies across {} threads",
                    task.name,
                    kinds.len(),
                    pool.threads()
                );
            }
            // build the fabric/topology once for the whole sweep and clone
            // per cell: the five strategy runs share one realized trace
            // (grids Arc-shared) instead of regenerating it per run
            let probe =
                task.config(workers, kinds[0].clone(), network.clone(), scale);
            let fabric = probe.network.build_fabric(workers)?;
            let topology = probe.network.build_topology(workers, &fabric)?;
            let runs = pool.map(kinds.len(), |i| {
                let cfg =
                    task.config(workers, kinds[i].clone(), network.clone(), scale);
                Self::run_analytic_on(
                    &cfg,
                    fabric.clone(),
                    topology.clone(),
                    Some(1),
                )
            });
            let mut out = Vec::new();
            for (kind, res) in kinds.iter().zip(runs) {
                let r = res?;
                if self.verbose {
                    eprintln!(
                        "[run] task={} strategy={} n={} -> iters={} \
                         vtime={:.1}s loss={:.4}",
                        task.name,
                        kind.label(),
                        workers,
                        r.total_iters,
                        r.total_time,
                        r.final_loss()
                    );
                }
                out.push((kind.label(), r));
            }
            return Ok(out);
        }
        let mut out = Vec::new();
        for kind in kinds {
            let label = kind.label();
            let cfg = task.config(workers, kind, network.clone(), scale);
            out.push((label, self.run(&cfg)?));
        }
        Ok(out)
    }
}

impl Default for ExpEnv {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::wan_network;

    #[test]
    fn quadratic_sweep_runs_and_orders() {
        let mut env = ExpEnv::new();
        env.verbose = false;
        let task = TaskSpec::quadratic();
        let net = wan_network(1e8, 0.2, 3);
        let rs = env.sweep_strategies(&task, 4, &net, 1.0).unwrap();
        assert_eq!(rs.len(), 5);
        let t = |label: &str| {
            rs.iter()
                .find(|(l, _)| *l == label)
                .and_then(|(_, r)| r.time_to_loss(task.loss_target))
        };
        let dsgd = t("D-SGD");
        let deco = t("DeCo-SGD");
        assert!(deco.is_some(), "DeCo-SGD must reach the target");
        if let (Some(d), Some(c)) = (dsgd, deco) {
            assert!(c < d, "DeCo {c} should beat D-SGD {d}");
        }
    }

    #[test]
    fn task_specs_resolve() {
        assert_eq!(TaskSpec::paper_tasks().len(), 4);
        assert!(TaskSpec::by_name("gpt_wikitext").is_some());
        assert!(TaskSpec::by_name("nope").is_none());
    }
}
