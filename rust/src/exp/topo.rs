//! `exp topo` — hierarchical multi-datacenter study (beyond the paper: its
//! testbed is a flat star, but its motivating setting is training *across*
//! data centers with cheap intra-region links and scarce WAN links).
//!
//! Sweeps region count × WAN:LAN bandwidth ratio × {flat D-SGD, flat
//! DeCo, two-tier DeCo} on a region-structured network:
//!
//! * **two-tier** runs price members on fast LAN links (`A_LAN`, `B_LAN`)
//!   and one full-rate WAN link per region (`ratio · A_LAN`, `B_WAN`) —
//!   only the δ_wan-compressed region partial crosses the WAN
//!   (DESIGN.md §Topology);
//! * **flat** runs price the same physical network as the star the repo
//!   used until now: every worker's gradient crosses the WAN itself, so a
//!   region's egress bandwidth is shared by its `m` concurrent flows
//!   (each worker link gets `ratio · A_LAN / m`) and each path pays the
//!   full `B_LAN + B_WAN` latency.
//!
//! Flat DeCo plans on the monitored bottleneck of that shared star —
//! bottleneck planning is not the limitation, the topology is: the WAN
//! transfer budget per iteration is split m ways. Two-tier DeCo re-unifies
//! it, so its WAN tier affords an m× larger δ_wan at the same cadence. The
//! `speedup` column is `t(flat DeCo) / t(two-tier DeCo)` — the win grows
//! as the WAN:LAN ratio drops and with more workers per region.
//!
//! Deterministic by construction: constant traces, pinned T_comp, the
//! analytic quadratic oracle (`tests/topo.rs` asserts byte-identical CSV
//! across two sweeps).

use crate::config::{
    FabricSpec, NetworkConfig, RegionSpec, TopologySpec,
};
use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::DecoInput;
use crate::exp::{results_dir, speedup};
use crate::metrics::{format_table, RunResult};
use crate::netsim::{Fabric, TraceKind};
use crate::optim::Quadratic;
use crate::strategy::StrategyKind;
use crate::topo::Topology;
use crate::util::WorkerPool;

/// Intra-region (LAN) links: 1 Gbps, 5 ms — cheap and fast.
const A_LAN: f64 = 1e9;
const B_LAN: f64 = 0.005;
/// WAN latency: 300 ms — the cross-datacenter hop the paper motivates.
const B_WAN: f64 = 0.3;
/// Pinned per-iteration compute time (s).
const T_COMP: f64 = 0.2;
/// Pinned gradient size (bits): 100 Mbit — a full gradient costs 0.1 s on
/// the LAN (half a T_comp) and is WAN-bound at every swept ratio.
const S_G: f64 = 1e8;
const GAMMA: f32 = 0.02;
/// Same loss target as the quadratic TaskSpec.
const TARGET: f64 = 0.18;
const UPDATE_EVERY: usize = 20;

/// WAN:LAN bandwidth ratio ladder, scarce last.
const RATIOS: [f64; 3] = [0.5, 0.1, 0.02];

/// The three comparison arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopoArm {
    /// flat star, no compression (the exact baseline)
    FlatDsgd,
    /// flat star, bottleneck-planned DeCo
    FlatDeco,
    /// two-tier topology, per-tier DeCo
    TwoTierDeco,
}

impl TopoArm {
    pub fn label(&self) -> &'static str {
        match self {
            Self::FlatDsgd => "D-SGD (flat)",
            Self::FlatDeco => "DeCo (flat)",
            Self::TwoTierDeco => "DeCo (2-tier)",
        }
    }
}

/// Split `n` workers into `regions` groups (remainder spread over the
/// leading groups).
pub fn region_sizes(n: usize, regions: usize) -> Vec<usize> {
    assert!(regions >= 1 && regions <= n);
    let base = n / regions;
    let rem = n % regions;
    (0..regions).map(|r| base + usize::from(r < rem)).collect()
}

/// The network config of one sweep point. Flat arms see the shared-egress
/// star (per-worker bandwidth `ratio · A_LAN / m`, full path latency);
/// the two-tier arm sees LAN member links plus the per-region WAN spec.
fn network(n: usize, regions: usize, ratio: f64, flat: bool) -> NetworkConfig {
    let a_wan = ratio * A_LAN;
    let groups = region_sizes(n, regions)
        .into_iter()
        .map(|m| {
            if flat {
                RegionSpec {
                    workers: m,
                    trace: TraceKind::Constant { bps: a_wan / m as f64 },
                    latency_s: B_LAN + B_WAN,
                }
            } else {
                RegionSpec {
                    workers: m,
                    trace: TraceKind::Constant { bps: A_LAN },
                    latency_s: B_LAN,
                }
            }
        })
        .collect();
    NetworkConfig {
        trace: TraceKind::Constant { bps: if flat { a_wan } else { A_LAN } },
        latency_s: if flat { B_LAN + B_WAN } else { B_LAN },
        fabric: FabricSpec::Regions { groups },
        topology: if flat {
            TopologySpec::Flat
        } else {
            TopologySpec::TwoTier {
                wan_trace: TraceKind::Constant { bps: a_wan },
                wan_latency_s: B_WAN,
                region_wan: Vec::new(),
            }
        },
        bonds: Vec::new(),
        losses: Vec::new(),
    }
}

/// The realized `(fabric, topology)` of one sweep point × arm shape; the
/// sweep builds each shape once and clones it per arm.
fn cell_network(
    workers: usize,
    regions: usize,
    ratio: f64,
    flat: bool,
) -> anyhow::Result<(Fabric, Topology)> {
    let net = network(workers, regions, ratio, flat);
    let fabric = net.build_fabric(workers)?;
    let topology = net.build_topology(workers, &fabric)?;
    Ok((fabric, topology))
}

/// One training run at a sweep point. `dim` is exposed so the tests can
/// shrink the oracle.
pub fn run_one(
    regions: usize,
    ratio: f64,
    arm: TopoArm,
    workers: usize,
    dim: usize,
    max_iters: usize,
) -> anyhow::Result<RunResult> {
    let flat = arm != TopoArm::TwoTierDeco;
    let (fabric, topology) = cell_network(workers, regions, ratio, flat)?;
    run_on(fabric, topology, regions, ratio, arm, dim, max_iters)
}

/// One training run on a prebuilt network (the sweep-cell body); the
/// worker count comes from the fabric itself.
fn run_on(
    fabric: Fabric,
    topology: Topology,
    regions: usize,
    ratio: f64,
    arm: TopoArm,
    dim: usize,
    max_iters: usize,
) -> anyhow::Result<RunResult> {
    let workers = fabric.workers();
    let flat = arm != TopoArm::TwoTierDeco;
    let kind = match arm {
        TopoArm::FlatDsgd => StrategyKind::DSgd,
        TopoArm::FlatDeco => {
            StrategyKind::DecoSgd { update_every: UPDATE_EVERY }
        }
        TopoArm::TwoTierDeco => {
            StrategyKind::DecoTwoTier { update_every: UPDATE_EVERY }
        }
    };
    let oracle = Quadratic::new(dim, workers, 0.5, 0.1, 0.3, 0.2, 7);
    let fallback = if flat {
        DecoInput {
            s_g: S_G,
            a: ratio * A_LAN / (workers as f64 / regions as f64),
            b: B_LAN + B_WAN,
            t_comp: T_COMP,
        }
    } else {
        DecoInput { s_g: S_G, a: A_LAN, b: B_LAN, t_comp: T_COMP }
    };
    let params = TrainParams {
        gamma: GAMMA,
        max_iters,
        log_every: 5,
        loss_target: Some(TARGET),
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        seed: 7,
        fallback,
        // runs fan out run-level over the pool (the sweep_strategies
        // pattern); each inner loop stays serial
        threads: Some(1),
        ..Default::default()
    };
    let mut tl = TrainLoop::try_with_topology(
        oracle,
        kind.build(),
        fabric,
        topology,
        params,
    )?;
    Ok(tl.run("quadratic"))
}

fn arms() -> Vec<TopoArm> {
    vec![TopoArm::FlatDsgd, TopoArm::FlatDeco, TopoArm::TwoTierDeco]
}

/// Push one checked CSV row: a row that disagrees with the header is a
/// hard error, never silent misalignment.
fn push_row(csv: &mut String, header_cols: usize, cells: &[String]) {
    assert_eq!(
        cells.len(),
        header_cols,
        "topo.csv row has {} cells for a {header_cols}-column header",
        cells.len()
    );
    csv.push_str(&cells.join(","));
    csv.push('\n');
}

/// The full sweep: returns `(csv, table_rows)`. Deterministic in
/// `(scale, workers, dim)`.
pub fn sweep(
    scale: f64,
    workers: usize,
    dim: usize,
) -> anyhow::Result<(String, Vec<Vec<String>>)> {
    let max_iters = ((6000.0 * scale) as usize).max(50);
    let arms = arms();
    let region_counts: Vec<usize> =
        [2usize, 4].into_iter().filter(|&r| r <= workers).collect();
    let n_combos = region_counts.len() * RATIOS.len() * arms.len();
    // realize each sweep point's two network shapes once (flat star +
    // two-tier), cloned per arm in combo order
    let mut nets: Vec<(Fabric, Topology)> = Vec::with_capacity(n_combos);
    for &regions in &region_counts {
        for &ratio in &RATIOS {
            let flat = cell_network(workers, regions, ratio, true)?;
            let two = cell_network(workers, regions, ratio, false)?;
            for &arm in &arms {
                nets.push(if arm == TopoArm::TwoTierDeco {
                    two.clone()
                } else {
                    flat.clone()
                });
            }
        }
    }
    let pool = WorkerPool::new(WorkerPool::default_threads().min(n_combos));
    eprintln!("[topo] {n_combos} runs across {} threads", pool.threads());
    let results = pool.map(n_combos, |i| {
        let arm = arms[i % arms.len()];
        let rest = i / arms.len();
        let ratio = RATIOS[rest % RATIOS.len()];
        let regions = region_counts[rest / RATIOS.len()];
        let (fabric, topology) = nets[i].clone();
        run_on(fabric, topology, regions, ratio, arm, dim, max_iters)
    });
    let mut results = results.into_iter();
    const HEADER: &str = "regions,ratio,wan_bps,strategy,time_to_target,\
                          total_iters,wan_gbits";
    let header_cols = HEADER.split(',').count();
    let mut csv = String::from(HEADER);
    csv.push('\n');
    let mut rows = Vec::new();
    for &regions in &region_counts {
        for &ratio in &RATIOS {
            let mut cells =
                vec![format!("{regions}R"), format!("1:{:.0}", 1.0 / ratio)];
            let mut times: Vec<Option<f64>> = Vec::new();
            for &arm in &arms {
                let res = results.next().expect("one result per combo")?;
                let t = res.time_to_loss(TARGET);
                // total bits that crossed the WAN tier: per-region columns
                // of the final record (two-tier), "-" for flat stars whose
                // every worker flow is WAN traffic by construction
                let wan_gbits = res
                    .records
                    .last()
                    .filter(|r| !r.regions.is_empty())
                    .map(|r| {
                        let bits: u64 =
                            r.regions.iter().map(|reg| reg.wan_bits).sum();
                        format!("{:.2}", bits as f64 / 1e9)
                    })
                    .unwrap_or_else(|| "-".into());
                push_row(
                    &mut csv,
                    header_cols,
                    &[
                        regions.to_string(),
                        ratio.to_string(),
                        format!("{:.0}", ratio * A_LAN),
                        arm.label().to_string(),
                        t.map(|v| format!("{v:.2}"))
                            .unwrap_or_else(|| "-".into()),
                        res.total_iters.to_string(),
                        wan_gbits,
                    ],
                );
                cells.push(
                    t.map(|v| format!("{v:.1}s"))
                        .unwrap_or_else(|| "-".into()),
                );
                times.push(t);
            }
            // how much hierarchical aggregation wins back over the flat
            // star under the same planner
            cells.push(speedup(times[1], times[2]));
            rows.push(cells);
        }
    }
    Ok((csv, rows))
}

pub fn main(scale: f64, workers: usize) -> anyhow::Result<()> {
    println!(
        "exp topo — region count x WAN:LAN ratio x strategy on a \
         {workers}-worker multi-datacenter network\n(LAN {:.0} Mbps / \
         {B_LAN} s per member; WAN = ratio x LAN per region, {B_WAN} s; \
         flat stars share each region's WAN egress across its workers; \
         time-to-loss {TARGET} on the quadratic; E = {UPDATE_EVERY})\n",
        A_LAN / 1e6
    );
    let (csv, rows) = sweep(scale, workers, 4096)?;
    println!(
        "{}",
        format_table(
            &[
                "topology",
                "wan:lan",
                "D-SGD (flat)",
                "DeCo (flat)",
                "DeCo (2-tier)",
                "speedup",
            ],
            &rows
        )
    );
    let path = results_dir().join("topo.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_sizes_partition_evenly() {
        assert_eq!(region_sizes(8, 2), vec![4, 4]);
        assert_eq!(region_sizes(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(region_sizes(7, 2), vec![4, 3]);
        assert_eq!(region_sizes(5, 4), vec![2, 1, 1, 1]);
        for (n, r) in [(8, 2), (7, 3), (9, 4)] {
            assert_eq!(region_sizes(n, r).iter().sum::<usize>(), n);
        }
    }

    #[test]
    fn network_specs_realize_both_shapes() {
        let flat = network(8, 2, 0.1, true);
        let f = flat.build_fabric(8).unwrap();
        // shared egress: 4 workers split 100 Mbps -> 25 Mbps each, full
        // path latency
        assert_eq!(f.bottleneck(0.0), (0.1 * A_LAN / 4.0, B_LAN + B_WAN));
        assert!(matches!(
            flat.build_topology(8, &f).unwrap(),
            crate::topo::Topology::Flat
        ));

        let two = network(8, 2, 0.1, false);
        let f = two.build_fabric(8).unwrap();
        assert_eq!(f.bottleneck(0.0), (A_LAN, B_LAN));
        let topo = two.build_topology(8, &f).unwrap();
        let crate::topo::Topology::TwoTier { regions, wan } = &topo else {
            panic!("expected two-tier")
        };
        assert_eq!(regions.len(), 2);
        // the region's single WAN flow gets the full egress bandwidth
        assert_eq!(wan.bottleneck(0.0), (0.1 * A_LAN, B_WAN));
    }

    #[test]
    fn two_tier_beats_flat_deco_on_a_scarce_wan() {
        // the headline: at WAN:LAN = 1:10 the flat star splits each
        // region's egress 2 ways (δ* ≈ 0.1 per worker flow) while
        // two-tier ships one partial at full rate (δ_wan ≈ 0.2) — the
        // per-tier planner pays roughly half the φ penalty and must reach
        // the target sooner
        let flat =
            run_one(2, 0.1, TopoArm::FlatDeco, 4, 512, 6000).unwrap();
        let two =
            run_one(2, 0.1, TopoArm::TwoTierDeco, 4, 512, 6000).unwrap();
        let tf = flat.time_to_loss(TARGET).expect("flat reaches");
        let tt = two.time_to_loss(TARGET).expect("two-tier reaches");
        assert!(
            tt < tf,
            "two-tier {tt:.1}s should beat flat {tf:.1}s"
        );
        // and the two-tier run's records carry the per-region columns
        let last = two.records.last().unwrap();
        assert_eq!(last.regions.len(), 2);
        assert!(last.regions.iter().all(|r| r.wan_bits > 0));
        assert!(last.wan_delta < 1.0, "the WAN tier compresses");
    }

    #[test]
    fn sweep_csv_is_rectangular() {
        let (csv, rows) = sweep(0.02, 4, 128).unwrap();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert_eq!(header.split(',').count(), 7);
        let mut n = 0;
        for line in lines {
            assert_eq!(line.split(',').count(), 7, "{line}");
            n += 1;
        }
        // 2 region counts (2 and 4 both fit n=4) x 3 ratios x 3 arms
        assert_eq!(n, 18);
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.len() == 6));
    }
}
