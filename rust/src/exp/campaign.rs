//! Resumable sweep campaigns (DESIGN.md §Perf).
//!
//! A large sweep is a sequence of deterministic *cells*, each contributing
//! rows to one campaign CSV. The engine checkpoints progress to an
//! append-only *journal*: an atomically-created header (fingerprint + CSV
//! header offset) followed by one `cell <offset> <id>` line appended and
//! flushed per completed cell — O(1) per cell where a rewrite-the-manifest
//! scheme is O(completed), i.e. O(cells²) over a campaign. A killed
//! campaign resumes where it stopped and produces a **byte-identical**
//! CSV: the resume drops any torn journal tail (a line without its
//! newline), truncates the CSV back to the last journaled offset
//! (discarding any torn tail row the kill left behind) and re-runs only
//! the unfinished cells. Rows must therefore be deterministic functions of
//! the cell — no wall-clock timestamps, no RNG outside the cell's own
//! seed. A journal whose fingerprint disagrees with the spec (the sweep's
//! shape changed under an old output directory) is a hard error, never a
//! silent partial reuse.

use std::collections::HashSet;
use std::fs;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const MANIFEST_MAGIC: &str = "deco-campaign v2";

/// The shape of a campaign: where it lives, what identifies its config,
/// and the ordered cell ids.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    /// output directory (created if missing)
    pub dir: PathBuf,
    /// campaign name: rows land in `<name>.csv`, progress in
    /// `<name>.manifest`
    pub name: String,
    /// single-line config fingerprint; resuming under a different
    /// fingerprint is a hard error
    pub fingerprint: String,
    /// CSV header line (no trailing newline)
    pub header: String,
    /// cell ids in execution order (unique, single-line)
    pub cells: Vec<String>,
    /// stop (checkpointed, resumable) after this many cells *this
    /// invocation* — the kill-simulation hook CI's resume test drives
    pub max_cells: Option<usize>,
}

impl CampaignSpec {
    pub fn csv_path(&self) -> PathBuf {
        self.dir.join(format!("{}.csv", self.name))
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.dir.join(format!("{}.manifest", self.name))
    }
}

/// How an invocation ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CampaignOutcome {
    /// every cell is done; the CSV is final
    Complete,
    /// `max_cells` hit first; rerun with the same spec to continue
    Paused { done: usize, total: usize },
}

struct Manifest {
    fingerprint: String,
    csv_bytes: u64,
    completed: Vec<String>,
}

impl Manifest {
    /// The journal prefix ending on the last newline — everything a
    /// resume may trust. A kill mid-append leaves a torn final line; the
    /// cell it was recording simply reruns.
    fn complete_lines(text: &str) -> &str {
        if text.ends_with('\n') {
            text
        } else {
            &text[..text.rfind('\n').map_or(0, |i| i + 1)]
        }
    }

    fn parse(text: &str, path: &Path) -> Result<Self> {
        let mut lines = Self::complete_lines(text).lines();
        if lines.next() != Some(MANIFEST_MAGIC) {
            bail!("{} is not a campaign journal", path.display());
        }
        let mut fingerprint = None;
        let mut csv_bytes = None;
        let mut completed = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            match line.split_once(' ') {
                Some(("fingerprint", v)) => fingerprint = Some(v.to_string()),
                Some(("csv_bytes", v)) => {
                    csv_bytes = Some(v.parse::<u64>().with_context(|| {
                        format!("bad csv_bytes in {}", path.display())
                    })?)
                }
                Some(("cell", v)) => {
                    let Some((bytes, id)) = v.split_once(' ') else {
                        bail!(
                            "unrecognized journal line {line:?} in {}",
                            path.display()
                        );
                    };
                    csv_bytes =
                        Some(bytes.parse::<u64>().with_context(|| {
                            format!("bad cell offset in {}", path.display())
                        })?);
                    completed.push(id.to_string());
                }
                _ => bail!(
                    "unrecognized journal line {line:?} in {}",
                    path.display()
                ),
            }
        }
        let (Some(fingerprint), Some(csv_bytes)) = (fingerprint, csv_bytes)
        else {
            bail!("incomplete campaign journal at {}", path.display());
        };
        Ok(Self { fingerprint, csv_bytes, completed })
    }
}

/// Run (or resume) a campaign. `run_cell(index, id)` produces the cell's
/// CSV rows (no trailing newlines); it runs once per *incomplete* cell, in
/// spec order, and its output is appended and checkpointed before the next
/// cell starts.
pub fn run_campaign(
    spec: &CampaignSpec,
    mut run_cell: impl FnMut(usize, &str) -> Result<Vec<String>>,
) -> Result<CampaignOutcome> {
    for id in &spec.cells {
        assert!(
            !id.contains('\n') && !id.is_empty(),
            "cell ids must be non-empty single lines"
        );
    }
    assert!(
        spec.cells.iter().collect::<HashSet<_>>().len() == spec.cells.len(),
        "cell ids must be unique"
    );
    fs::create_dir_all(&spec.dir)
        .with_context(|| format!("creating {}", spec.dir.display()))?;
    let csv_path = spec.csv_path();
    let manifest_path = spec.manifest_path();

    let mut manifest = if manifest_path.exists() {
        let text = fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let m = Manifest::parse(&text, &manifest_path)?;
        if m.fingerprint != spec.fingerprint {
            bail!(
                "campaign at {} was started with a different configuration \
                 (journal fingerprint {:?}, current {:?}); point the sweep \
                 at a fresh directory or delete the stale campaign",
                spec.dir.display(),
                m.fingerprint,
                spec.fingerprint
            );
        }
        for id in &m.completed {
            if !spec.cells.contains(id) {
                bail!(
                    "journal at {} records completed cell {id:?} the \
                     current spec doesn't contain",
                    manifest_path.display()
                );
            }
        }
        // drop any torn journal tail so appends resume on a line boundary
        let valid = Manifest::complete_lines(&text).len() as u64;
        let j = fs::OpenOptions::new()
            .write(true)
            .open(&manifest_path)
            .with_context(|| {
                format!("opening {}", manifest_path.display())
            })?;
        j.set_len(valid)?;
        m
    } else {
        Manifest {
            fingerprint: spec.fingerprint.clone(),
            csv_bytes: 0,
            completed: Vec::new(),
        }
    };

    let mut csv = fs::OpenOptions::new()
        .create(true)
        .read(true)
        .write(true)
        .open(&csv_path)
        .with_context(|| format!("opening {}", csv_path.display()))?;
    if manifest.completed.is_empty() && manifest.csv_bytes == 0 {
        // fresh campaign: (re)write the header, then commit the journal
        // header atomically (temp file + rename), so even a kill inside
        // the first cell resumes cleanly
        csv.set_len(0)?;
        csv.write_all(spec.header.as_bytes())?;
        csv.write_all(b"\n")?;
        csv.flush()?;
        manifest.csv_bytes = csv.stream_position()?;
        let tmp = manifest_path.with_extension("manifest.tmp");
        fs::write(
            &tmp,
            format!(
                "{MANIFEST_MAGIC}\nfingerprint {}\ncsv_bytes {}\n",
                manifest.fingerprint, manifest.csv_bytes
            ),
        )
        .with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &manifest_path).with_context(|| {
            format!("committing {}", manifest_path.display())
        })?;
    } else {
        // resume: drop any torn tail the kill left past the checkpoint
        csv.set_len(manifest.csv_bytes)?;
        csv.seek(SeekFrom::Start(manifest.csv_bytes))?;
    }
    // held open for the whole invocation: every completed cell appends
    // exactly one flushed line
    let mut journal = fs::OpenOptions::new()
        .append(true)
        .open(&manifest_path)
        .with_context(|| format!("opening {}", manifest_path.display()))?;

    let done: HashSet<String> = manifest.completed.iter().cloned().collect();
    let total = spec.cells.len();
    let mut ran = 0usize;
    for (i, id) in spec.cells.iter().enumerate() {
        if done.contains(id) {
            continue;
        }
        if let Some(max) = spec.max_cells {
            if ran >= max {
                return Ok(CampaignOutcome::Paused {
                    done: manifest.completed.len(),
                    total,
                });
            }
        }
        let rows = run_cell(i, id)
            .with_context(|| format!("campaign cell {id:?}"))?;
        for row in &rows {
            csv.write_all(row.as_bytes())?;
            csv.write_all(b"\n")?;
        }
        csv.flush()?;
        manifest.csv_bytes = csv.stream_position()?;
        manifest.completed.push(id.clone());
        journal.write_all(
            format!("cell {} {id}\n", manifest.csv_bytes).as_bytes(),
        )?;
        journal.flush()?;
        ran += 1;
    }
    Ok(CampaignOutcome::Complete)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dir: &Path, max_cells: Option<usize>) -> CampaignSpec {
        CampaignSpec {
            dir: dir.to_path_buf(),
            name: "demo".into(),
            fingerprint: "demo-v1 cells=3".into(),
            header: "cell,value".into(),
            cells: vec!["a".into(), "b".into(), "c".into()],
            max_cells,
        }
    }

    fn cell_rows(i: usize, id: &str) -> Result<Vec<String>> {
        Ok(vec![format!("{id},{}", i * 10), format!("{id},{}", i * 10 + 1)])
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "deco_campaign_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn killed_campaign_resumes_byte_identical() {
        let straight = tmp_dir("straight");
        let s = spec(&straight, None);
        assert_eq!(
            run_campaign(&s, cell_rows).unwrap(),
            CampaignOutcome::Complete
        );
        let reference = fs::read(s.csv_path()).unwrap();

        // same campaign, "killed" after one cell per invocation
        let chunked = tmp_dir("chunked");
        let k = spec(&chunked, Some(1));
        assert_eq!(
            run_campaign(&k, cell_rows).unwrap(),
            CampaignOutcome::Paused { done: 1, total: 3 }
        );
        // simulate a torn row from a kill mid-append: the resume must
        // truncate it away
        {
            let mut f = fs::OpenOptions::new()
                .append(true)
                .open(k.csv_path())
                .unwrap();
            f.write_all(b"b,partial-garbage").unwrap();
        }
        assert_eq!(
            run_campaign(&k, cell_rows).unwrap(),
            CampaignOutcome::Paused { done: 2, total: 3 }
        );
        assert_eq!(
            run_campaign(&k, cell_rows).unwrap(),
            CampaignOutcome::Complete
        );
        assert_eq!(fs::read(k.csv_path()).unwrap(), reference);
        // idempotent once complete: no cells rerun, bytes untouched
        let reran = run_campaign(&k, |_, id| {
            panic!("cell {id} must not rerun after completion")
        })
        .unwrap();
        assert_eq!(reran, CampaignOutcome::Complete);
        assert_eq!(fs::read(k.csv_path()).unwrap(), reference);
        // the journal is append-only: exactly one line per completed cell
        let journal = fs::read_to_string(k.manifest_path()).unwrap();
        assert_eq!(
            journal.lines().filter(|l| l.starts_with("cell ")).count(),
            3,
            "one journal line per cell:\n{journal}"
        );

        let _ = fs::remove_dir_all(&straight);
        let _ = fs::remove_dir_all(&chunked);
    }

    #[test]
    fn torn_journal_line_reruns_the_cell() {
        // kill mid-append of cell "b"'s journal line: its rows reached
        // the CSV but the record is torn — the resume must drop both and
        // rerun the cell, landing byte-identical to a straight run
        let dir = tmp_dir("torn_journal");
        let s = spec(&dir, Some(1));
        run_campaign(&s, cell_rows).unwrap();
        {
            let mut f = fs::OpenOptions::new()
                .append(true)
                .open(s.csv_path())
                .unwrap();
            f.write_all(b"b,10\nb,11\n").unwrap();
            let mut j = fs::OpenOptions::new()
                .append(true)
                .open(s.manifest_path())
                .unwrap();
            j.write_all(b"cell 9").unwrap(); // no trailing newline
        }
        let full = spec(&dir, None);
        assert_eq!(
            run_campaign(&full, cell_rows).unwrap(),
            CampaignOutcome::Complete
        );
        let straight = tmp_dir("torn_journal_ref");
        let r = spec(&straight, None);
        run_campaign(&r, cell_rows).unwrap();
        assert_eq!(
            fs::read(full.csv_path()).unwrap(),
            fs::read(r.csv_path()).unwrap()
        );
        let _ = fs::remove_dir_all(&dir);
        let _ = fs::remove_dir_all(&straight);
    }

    #[test]
    fn fingerprint_mismatch_is_a_hard_error() {
        let dir = tmp_dir("fingerprint");
        let s = spec(&dir, Some(1));
        run_campaign(&s, cell_rows).unwrap();
        let mut changed = spec(&dir, None);
        changed.fingerprint = "demo-v2 cells=3".into();
        let err = run_campaign(&changed, cell_rows).unwrap_err();
        assert!(err.to_string().contains("different configuration"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_completed_cell_is_rejected() {
        let dir = tmp_dir("unknown_cell");
        let s = spec(&dir, None);
        run_campaign(&s, cell_rows).unwrap();
        let mut shrunk = spec(&dir, None);
        shrunk.cells.pop();
        let err = run_campaign(&shrunk, cell_rows).unwrap_err();
        assert!(err.to_string().contains("doesn't contain"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
