//! φ validation — the paper's central theoretical claim (Theorem 1/2,
//! Remark 1): the convergence of DD-EF-SGD is governed by
//! `φ(δ, τ) = (1−δ)/(δ(1−δ/2)^τ)` — *staleness exponentially amplifies
//! compression noise*. On the strongly-convex quadratic testbed the
//! cleanest observable is the **steady-state excess loss** (noise floor),
//! which the theory predicts scales with `φ·(ζ²/δ + σ²)` (the `φ' = φ/δ`
//! variant when heterogeneity dominates, Remark 1):
//!
//! * δ-sweep at fixed τ — floor grows as δ shrinks, tracking φ';
//! * τ-sweep at fixed δ — floor creeps up linearly-ish for small τ, then
//!   *explodes* once `(1−δ/2)^{−τ}` takes over (and finally diverges),
//!   which is exactly the paper's headline amplification.
//!
//! `iters_to_target` (time-to-ε) is also provided and used by the
//! theory_playground example.

use crate::compress::{ErrorFeedback, TopK};
use crate::deco::phi::{phi, phi_prime};
use crate::exp::results_dir;
use crate::optim::{GradOracle, Quadratic};
use crate::util::Rng;
use std::collections::VecDeque;

pub struct PhiRow {
    pub delta: f64,
    pub tau: usize,
    pub phi: f64,
    pub phi_prime: f64,
    /// steady-state excess loss E[f(x) − f*] at the noise floor
    pub floor: f64,
}

fn testbed() -> Quadratic {
    Quadratic::new(512, 4, 0.5, 0.1, 0.3, 1.0, 31)
}

/// Run DD-EF-SGD and return the steady-state excess loss (mean over the
/// tail third of the run). Returns +inf when the trajectory diverges.
pub fn steady_state_excess(
    oracle: &mut Quadratic,
    delta: f64,
    tau: usize,
    gamma: f32,
    iters: usize,
) -> f64 {
    let dim = oracle.dim();
    let n = oracle.workers();
    let f_star = oracle.f_star();
    let comp = TopK::new(delta);
    let mut efs: Vec<ErrorFeedback> =
        (0..n).map(|_| ErrorFeedback::new(dim)).collect();
    let mut queues: Vec<VecDeque<Vec<f32>>> =
        (0..n).map(|_| VecDeque::new()).collect();
    let mut rng = Rng::new(0x9191);
    let mut x = oracle.init();
    let mut g = vec![0.0f32; dim];
    let mut agg = vec![0.0f32; dim];
    let mut tail_sum = 0.0f64;
    let mut tail_n = 0usize;
    for t in 1..=iters {
        for w in 0..n {
            oracle.grad(w, t, &x, &mut g);
            queues[w].push_back(g.clone());
        }
        agg.iter_mut().for_each(|v| *v = 0.0);
        let mut any = false;
        let scale = 1.0 / n as f32;
        for w in 0..n {
            if queues[w].len() > tau {
                let mut old = queues[w].pop_front().unwrap();
                efs[w].step(&mut old, &comp, &mut rng);
                for (a, v) in agg.iter_mut().zip(&old) {
                    *a += scale * *v;
                }
                any = true;
            }
        }
        if any {
            for (xi, ai) in x.iter_mut().zip(&agg) {
                *xi -= gamma * ai;
            }
        }
        if t > iters - iters / 3 && t % 10 == 0 {
            let l = oracle.loss(&x);
            if !l.is_finite() {
                return f64::INFINITY;
            }
            tail_sum += l - f_star;
            tail_n += 1;
        }
    }
    if tail_n == 0 { f64::INFINITY } else { tail_sum / tail_n as f64 }
}

/// Iterations until `loss <= target` (used by theory_playground).
pub fn iters_to_target(
    oracle: &mut Quadratic,
    delta: f64,
    tau: usize,
    gamma: f32,
    target: f64,
    max_iters: usize,
) -> (Option<usize>, f64) {
    let dim = oracle.dim();
    let n = oracle.workers();
    let comp = TopK::new(delta);
    let mut efs: Vec<ErrorFeedback> =
        (0..n).map(|_| ErrorFeedback::new(dim)).collect();
    let mut queues: Vec<VecDeque<Vec<f32>>> =
        (0..n).map(|_| VecDeque::new()).collect();
    let mut rng = Rng::new(0x9191);
    let mut x = oracle.init();
    let mut g = vec![0.0f32; dim];
    let mut agg = vec![0.0f32; dim];
    let mut last = f64::INFINITY;
    for t in 1..=max_iters {
        for w in 0..n {
            oracle.grad(w, t, &x, &mut g);
            queues[w].push_back(g.clone());
        }
        agg.iter_mut().for_each(|v| *v = 0.0);
        let mut any = false;
        let scale = 1.0 / n as f32;
        for w in 0..n {
            if queues[w].len() > tau {
                let mut old = queues[w].pop_front().unwrap();
                efs[w].step(&mut old, &comp, &mut rng);
                for (a, v) in agg.iter_mut().zip(&old) {
                    *a += scale * *v;
                }
                any = true;
            }
        }
        if any {
            for (xi, ai) in x.iter_mut().zip(&agg) {
                *xi -= gamma * ai;
            }
        }
        if t % 10 == 0 {
            last = oracle.loss(&x);
            if last <= target {
                return (Some(t), last);
            }
            if !last.is_finite() {
                return (None, last);
            }
        }
    }
    (None, last)
}

pub fn delta_sweep(gamma: f32, tau: usize, iters: usize) -> Vec<PhiRow> {
    [1.0, 0.5, 0.2, 0.1, 0.05, 0.02]
        .iter()
        .map(|&delta| {
            let mut o = testbed();
            PhiRow {
                delta,
                tau,
                phi: phi(delta, tau),
                phi_prime: phi_prime(delta, tau),
                floor: steady_state_excess(&mut o, delta, tau, gamma, iters),
            }
        })
        .collect()
}

pub fn tau_sweep(gamma: f32, delta: f64, iters: usize) -> Vec<PhiRow> {
    [0usize, 8, 16, 24, 32, 48]
        .iter()
        .map(|&tau| {
            let mut o = testbed();
            PhiRow {
                delta,
                tau,
                phi: phi(delta, tau),
                phi_prime: phi_prime(delta, tau),
                floor: steady_state_excess(&mut o, delta, tau, gamma, iters),
            }
        })
        .collect()
}

fn print_rows(rows: &[PhiRow], csv: &mut String) {
    println!(
        "{:>7} {:>4} {:>12} {:>12} {:>14}",
        "delta", "tau", "phi", "phi'", "excess floor"
    );
    for r in rows {
        let f = if r.floor.is_finite() {
            format!("{:.6}", r.floor)
        } else {
            "diverged".into()
        };
        println!(
            "{:>7} {:>4} {:>12.2} {:>12.2} {:>14}",
            r.delta, r.tau, r.phi, r.phi_prime, f
        );
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            r.delta, r.tau, r.phi, r.phi_prime, r.floor
        ));
    }
}

pub fn main() -> anyhow::Result<()> {
    let gamma = 0.1;
    let iters = 4000;
    let mut csv = String::from("delta,tau,phi,phi_prime,excess_floor\n");
    println!(
        "phi — steady-state excess loss vs phi (quadratic testbed, \
         gamma={gamma}, L=0.5, mu=0.1, sigma=0.3, zeta=1.0)\n"
    );
    println!("== delta sweep at tau=8 (floor tracks phi' = phi/delta) ==");
    print_rows(&delta_sweep(gamma, 8, iters), &mut csv);
    println!(
        "\n== tau sweep at delta=0.2 (exponential amplification: the floor \
         explodes once (1-delta/2)^-tau dominates) =="
    );
    print_rows(&tau_sweep(gamma, 0.2, iters), &mut csv);
    let path = results_dir().join("phi_validation.csv");
    std::fs::write(&path, csv)?;
    println!("\nwrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn floor_tracks_phi_in_delta() {
        // more aggressive compression (smaller δ) ⇒ strictly larger noise
        // floor at fixed τ
        let rows = super::delta_sweep(0.1, 8, 2500);
        let f = |d: f64| {
            rows.iter().find(|r| r.delta == d).unwrap().floor
        };
        assert!(f(0.02) > f(0.1), "{} !> {}", f(0.02), f(0.1));
        assert!(f(0.1) > f(1.0), "{} !> {}", f(0.1), f(1.0));
        assert!(f(1.0).is_finite());
    }

    #[test]
    fn staleness_amplifies_exponentially() {
        // the paper's headline: at fixed δ the floor is nearly flat for
        // small τ, then explodes
        let rows = super::tau_sweep(0.1, 0.2, 2500);
        let f = |t: usize| rows.iter().find(|r| r.tau == t).unwrap().floor;
        assert!(f(8) < 10.0 * f(0), "small tau must be benign");
        assert!(
            f(32) > 5.0 * f(0),
            "tau=32 floor {} should dwarf tau=0 {}",
            f(32),
            f(0)
        );
        // far tail diverges or is far worse still
        assert!(!f(48).is_finite() || f(48) > 10.0 * f(32));
    }
}
