//! Fig. 5 / appendix Fig. 7–8 — scalability: time-to-target as the worker
//! count scales 4 → 32 (fixed 200 ms latency, ~100 Mbps fluctuating
//! bandwidth), for GPT and ViT tasks.

use crate::config::wan_network;
use crate::exp::runner::{ExpEnv, TaskSpec};
use crate::exp::{results_dir, speedup};
use crate::metrics::format_table;

pub fn main(scale: f64, node_counts: &[usize]) -> anyhow::Result<()> {
    let mut env = ExpEnv::new();
    let counts: Vec<usize> = if node_counts.is_empty() {
        vec![4, 8, 16, 32]
    } else {
        node_counts.to_vec()
    };
    let tasks: Vec<TaskSpec> = ["gpt_wikitext", "vit_imagenet"]
        .iter()
        .filter_map(|n| TaskSpec::by_name(n))
        .collect();
    let mut rows = Vec::new();
    let mut csv =
        String::from("task,workers,method,time_to_target,total_iters\n");
    for task in &tasks {
        for &n in &counts {
            // paper Sec. 5.3: 200 ms, bandwidth fluctuating around 100 Mbps
            let net = crate::config::NetworkConfig::homogeneous(
                crate::netsim::TraceKind::Markov {
                    levels_bps: vec![5e7, 1e8, 2e8],
                    dwell_s: 40.0,
                    seed: 13 + n as u64,
                },
                0.2,
            );
            let _ = wan_network;
            let results = env.sweep_strategies(task, n, &net, scale)?;
            let time_of = |label: &str| {
                results
                    .iter()
                    .find(|(l, _)| *l == label)
                    .and_then(|(_, r)| r.time_to_loss(task.loss_target))
            };
            let (t_dsgd, t_cocktail, t_deco) = (
                time_of("D-SGD"),
                time_of("CocktailSGD"),
                time_of("DeCo-SGD"),
            );
            for (label, r) in &results {
                let t = r.time_to_loss(task.loss_target);
                csv.push_str(&format!(
                    "{},{},{},{},{}\n",
                    task.name,
                    n,
                    label,
                    t.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                    r.total_iters
                ));
            }
            rows.push(vec![
                task.label.to_string(),
                n.to_string(),
                t_deco
                    .map(|v| format!("{v:.1}s"))
                    .unwrap_or_else(|| "-".into()),
                speedup(t_dsgd, t_deco),
                speedup(t_cocktail, t_deco),
            ]);
        }
    }
    println!("Fig.5 — scalability (200 ms, ~100 Mbps OU)\n");
    println!(
        "{}",
        format_table(
            &["task", "n", "DeCo time", "speedup vs D-SGD", "vs Cocktail"],
            &rows
        )
    );
    let path = results_dir().join("fig5_scalability.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}
