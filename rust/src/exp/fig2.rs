//! Fig. 2 — running timelines for D-SGD, D-EF-SGD, DD-SGD and DD-EF-SGD
//! under one network condition, showing how compression shrinks the `=`
//! segments and staleness overlaps them with compute.

use crate::exp::results_dir;
use crate::timesim::timeline::{render_ascii, rows};
use crate::timesim::PipelineParams;

pub fn variants(
    a: f64,
    b: f64,
    t_comp: f64,
    s_g: f64,
    delta: f64,
    tau: usize,
) -> Vec<(&'static str, PipelineParams)> {
    vec![
        ("D-SGD", PipelineParams { a, b, delta: 1.0, tau: 0, t_comp, s_g }),
        ("D-EF-SGD", PipelineParams { a, b, delta, tau: 0, t_comp, s_g }),
        ("DD-SGD", PipelineParams { a, b, delta: 1.0, tau, t_comp, s_g }),
        ("DD-EF-SGD", PipelineParams { a, b, delta, tau, t_comp, s_g }),
    ]
}

pub fn main() -> anyhow::Result<()> {
    let (a, b, t_comp, s_g) = (1e9, 0.3, 0.25, 124e6 * 32.0);
    let (delta, tau) = (0.1, 2);
    println!(
        "Fig.2 — running timelines (a={} Gbps, b={b}s, T_comp={t_comp}s, \
         delta={delta}, tau={tau})",
        a / 1e9
    );
    println!("legend: # compute   = transmit   . latency\n");
    let mut csv =
        String::from("variant,iter,comp_start,comp_end,tx_start,tx_end,arrival\n");
    for (name, p) in variants(a, b, t_comp, s_g, delta, tau) {
        println!("{name}  (T_avg model: {:.3}s/iter)", crate::timesim::t_avg_closed_form(&p));
        println!("{}", render_ascii(&p, 8, 100));
        for r in rows(&p, 8) {
            csv.push_str(&format!(
                "{name},{},{:.4},{:.4},{:.4},{:.4},{:.4}\n",
                r.iter, r.comp_start, r.comp_end, r.tx_start, r.tx_end, r.arrival
            ));
        }
    }
    let path = results_dir().join("fig2_timelines.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timesim::{t_avg_closed_form, EventSim};

    #[test]
    fn variant_ordering_matches_fig2() {
        // D-SGD slowest; adding EF or delay speeds it up; both together
        // fastest — under WAN conditions
        let vs = variants(1e9, 0.3, 0.25, 124e6 * 32.0, 0.1, 2);
        let times: Vec<f64> = vs
            .iter()
            .map(|(_, p)| EventSim::run(p, 200).total_time())
            .collect();
        let (dsgd, defsgd, ddsgd, ddefsgd) =
            (times[0], times[1], times[2], times[3]);
        assert!(defsgd < dsgd, "compression must help");
        assert!(ddsgd < dsgd, "delay must help");
        assert!(ddefsgd < defsgd && ddefsgd < ddsgd, "both best");
    }

    #[test]
    fn closed_form_matches_each_variant() {
        for (_, p) in variants(5e8, 0.2, 0.3, 86e6 * 32.0, 0.05, 3) {
            let sim = EventSim::run(&p, 4000).t_avg();
            let model = t_avg_closed_form(&p);
            assert!(
                (sim - model).abs() / model < 0.02,
                "{p:?}: {sim} vs {model}"
            );
        }
    }
}
