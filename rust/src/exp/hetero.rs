//! `exp hetero` — heterogeneity study (beyond the paper: its Limitations
//! section defers per-node bandwidth/latency heterogeneity).
//!
//! Sweeps straggler severity × strategy on a per-worker fabric: worker 0's
//! link gets `frac`× the bandwidth and `mult`× the latency of the others,
//! and the fabric-driven Eq. 19 recurrence prices every iteration at the
//! **slowest** worker's arrival. The interesting comparison is DeCo-SGD
//! planning on the monitored **bottleneck** `(min a, max b)` — which is
//! what actually gates the synchronous aggregation — versus the same
//! controller planning on the heterogeneity-blind **mean link**. The
//! mean-link planner overestimates the usable bandwidth (δ too large, so
//! the straggler's transmission outlasts T_comp) and underestimates the
//! gating latency (τ too small, so every iteration stalls on the delayed
//! aggregation); the bottleneck planner keeps the pipeline bubble-free at
//! the straggler's pace. The `recovery` column is
//! `t(mean-link) / t(bottleneck)` — how much fabric-aware planning wins
//! back.
//!
//! Deterministic by construction: constant base trace, pinned T_comp, the
//! analytic quadratic oracle.

use crate::config::{FabricSpec, NetworkConfig};
use crate::coordinator::{TrainLoop, TrainParams};
use crate::deco::DecoInput;
use crate::exp::{results_dir, speedup};
use crate::metrics::{format_table, RunResult};
use crate::netsim::{Fabric, TraceKind};
use crate::optim::Quadratic;
use crate::strategy::{PlanBasis, StrategyKind};
use crate::util::WorkerPool;

/// Base (healthy-link) network: 100 Mbps, 150 ms — WAN-ish but fast enough
/// that the straggler, not the base link, is the story.
const BASE_BPS: f64 = 1e8;
const BASE_LAT: f64 = 0.15;
/// Pinned per-iteration compute time (s).
const T_COMP: f64 = 0.2;
/// Pinned gradient size (bits): 20 Mbit ⇒ a full gradient takes exactly
/// one T_comp on a healthy link, so both the δ and the τ channel of the
/// planner matter.
const S_G: f64 = 2e7;
const GAMMA: f32 = 0.02;
/// Same loss target as the quadratic TaskSpec.
const TARGET: f64 = 0.18;

/// Severity ladder: (label, frac, mult) for the straggler link. Labels are
/// comma-free — they land in the first CSV column verbatim.
fn severities(mult: f64) -> Vec<(String, f64, f64)> {
    vec![
        ("homogeneous".into(), 1.0, 1.0),
        (format!("bw 1/2 + lat {mult:.0}x"), 0.5, mult),
        (format!("bw 1/4 + lat {mult:.0}x"), 0.25, mult),
        (format!("bw 1/10 + lat {mult:.0}x"), 0.1, mult),
    ]
}

/// The straggler fabric of one severity point, built from the config
/// layer. Sweeps call this once per severity and clone the result per arm
/// (trace payloads are shared, see DESIGN.md §Perf).
pub fn severity_fabric(
    frac: f64,
    mult: f64,
    workers: usize,
) -> anyhow::Result<Fabric> {
    let fabric_spec = if frac == 1.0 && mult == 1.0 {
        FabricSpec::Homogeneous
    } else {
        FabricSpec::Straggler { frac, mult }
    };
    let net = NetworkConfig {
        trace: TraceKind::Constant { bps: BASE_BPS },
        latency_s: BASE_LAT,
        fabric: fabric_spec,
        topology: crate::config::TopologySpec::Flat,
        bonds: Vec::new(),
        losses: Vec::new(),
    };
    net.build_fabric(workers)
}

/// One training run on the straggler fabric. `dim` is exposed so the unit
/// test can shrink the oracle.
pub fn run_one(
    frac: f64,
    mult: f64,
    kind: StrategyKind,
    plan: PlanBasis,
    workers: usize,
    dim: usize,
    max_iters: usize,
) -> anyhow::Result<RunResult> {
    let fabric = severity_fabric(frac, mult, workers)?;
    Ok(run_on(fabric, kind, plan, dim, max_iters))
}

/// One training run on a prebuilt fabric (the sweep-cell body).
fn run_on(
    fabric: Fabric,
    kind: StrategyKind,
    plan: PlanBasis,
    dim: usize,
    max_iters: usize,
) -> RunResult {
    let workers = fabric.workers();
    let oracle = Quadratic::new(dim, workers, 0.5, 0.1, 0.3, 0.2, 7);
    let params = TrainParams {
        gamma: GAMMA,
        max_iters,
        log_every: 5,
        loss_target: Some(TARGET),
        max_virtual_time: None,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        paper_wire: true,
        block_topk: false,
        clip_norm: None,
        seed: 7,
        fallback: DecoInput { s_g: S_G, a: BASE_BPS, b: BASE_LAT, t_comp: T_COMP },
        monitor_alpha: 0.3,
        plan,
        // runs fan out run-level over the pool (like sweep_strategies);
        // each inner loop stays serial to avoid oversubscription
        threads: Some(1),
        ..Default::default()
    };
    let mut tl = TrainLoop::with_fabric(oracle, kind.build(), fabric, params);
    tl.run("quadratic")
}

fn arms() -> Vec<(&'static str, StrategyKind, PlanBasis)> {
    vec![
        ("D-SGD", StrategyKind::DSgd, PlanBasis::Bottleneck),
        ("CocktailSGD", StrategyKind::CocktailSgd, PlanBasis::Bottleneck),
        (
            "DeCo (mean-link)",
            StrategyKind::DecoSgd { update_every: 20 },
            PlanBasis::MeanLink,
        ),
        (
            "DeCo (bottleneck)",
            StrategyKind::DecoSgd { update_every: 20 },
            PlanBasis::Bottleneck,
        ),
    ]
}

/// Cell-pool size for `n_combos` sweep cells — shared by [`sweep`] and
/// the `main` log line so the printed thread count can never drift from
/// the pool the sweep actually builds.
fn pool_threads(n_combos: usize, threads: Option<usize>) -> usize {
    threads.unwrap_or_else(WorkerPool::default_threads).min(n_combos)
}

/// The full severity × arm sweep: returns `(csv, table_rows)`.
/// Deterministic in `(scale, workers, dim, mult)` at any pool size.
///
/// All severity × arm runs are independent analytic `TrainLoop`s: they fan
/// out run-level over the pool (the `sweep_strategies` pattern) with one
/// prebuilt fabric per severity, cloned per arm. `threads` pins the cell
/// pool — `Some(1)` is the serial baseline `benches/bench_trace.rs`
/// measures the pooled sweep against; `None` uses the machine default.
pub fn sweep(
    scale: f64,
    workers: usize,
    dim: usize,
    mult: f64,
    threads: Option<usize>,
) -> anyhow::Result<(String, Vec<Vec<String>>)> {
    let max_iters = ((6000.0 * scale) as usize).max(50);
    let arms = arms();
    let sevs = severities(mult);
    let fabrics = sevs
        .iter()
        .map(|(_, frac, smult)| severity_fabric(*frac, *smult, workers))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let n_combos = sevs.len() * arms.len();
    let pool = WorkerPool::new(pool_threads(n_combos, threads));
    let results = pool.map(n_combos, |i| {
        let fabric = fabrics[i / arms.len()].clone();
        let (_, kind, plan) = &arms[i % arms.len()];
        run_on(fabric, kind.clone(), *plan, dim, max_iters)
    });
    let mut results = results.into_iter();
    let mut rows = Vec::new();
    let mut csv = String::from(
        "severity,frac,mult,strategy,time_to_target,total_iters\n",
    );
    for (label, frac, smult) in &sevs {
        let mut times: Vec<Option<f64>> = Vec::new();
        let mut cells = vec![label.clone()];
        for (arm, _, _) in &arms {
            let res = results.next().expect("one result per combo");
            let t = res.time_to_loss(TARGET);
            csv.push_str(&format!(
                "{label},{frac},{smult},{arm},{},{}\n",
                t.map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "-".into()),
                res.total_iters
            ));
            cells.push(
                t.map(|v| format!("{v:.1}s"))
                    .unwrap_or_else(|| "-".into()),
            );
            times.push(t);
        }
        // recovery: how much the fabric-aware planner wins back over the
        // heterogeneity-blind one (mean-link time / bottleneck time)
        cells.push(speedup(times[2], times[3]));
        rows.push(cells);
    }
    Ok((csv, rows))
}

pub fn main(scale: f64, workers: usize, mult: f64) -> anyhow::Result<()> {
    println!(
        "exp hetero — straggler severity x strategy on a {workers}-worker \
         fabric\n(base {:.0} Mbps / {BASE_LAT} s, straggler = worker 0; \
         time-to-loss {TARGET} on the quadratic)\n",
        BASE_BPS / 1e6
    );
    let n_combos = severities(mult).len() * arms().len();
    eprintln!(
        "[hetero] {n_combos} runs across {} threads",
        pool_threads(n_combos, None)
    );
    let (csv, rows) = sweep(scale, workers, 4096, mult, None)?;
    println!(
        "{}",
        format_table(
            &[
                "straggler",
                "D-SGD",
                "CocktailSGD",
                "DeCo (mean-link)",
                "DeCo (bottleneck)",
                "recovery",
            ],
            &rows
        )
    );
    let path = results_dir().join("hetero_straggler.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_table_shapes() {
        let s = severities(6.0);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].1, 1.0);
        assert!(s.windows(2).all(|w| w[1].1 < w[0].1), "fracs decrease");
    }

    #[test]
    fn sweep_serial_equals_pooled() {
        // the serial-vs-pooled knob must not change a byte of the CSV:
        // cells are independent runs and prebuilt fabrics clone valuewise
        let (serial, _) = sweep(0.008, 4, 128, 6.0, Some(1)).unwrap();
        let (pooled, _) = sweep(0.008, 4, 128, 6.0, None).unwrap();
        assert_eq!(serial, pooled, "pool size leaked into the results");
    }

    #[test]
    fn homogeneous_plans_agree() {
        // with identical links the two planning bases coincide, so the
        // recovery ratio of the homogeneous row is ~1
        let bot = run_one(
            1.0,
            1.0,
            StrategyKind::DecoSgd { update_every: 20 },
            PlanBasis::Bottleneck,
            4,
            512,
            3000,
        )
        .unwrap();
        let mean = run_one(
            1.0,
            1.0,
            StrategyKind::DecoSgd { update_every: 20 },
            PlanBasis::MeanLink,
            4,
            512,
            3000,
        )
        .unwrap();
        let tb = bot.time_to_loss(TARGET).expect("bottleneck reaches");
        let tm = mean.time_to_loss(TARGET).expect("mean reaches");
        assert!(
            ((tb - tm) / tb).abs() < 1e-6,
            "homogeneous: {tb} vs {tm}"
        );
    }

    #[test]
    fn bottleneck_beats_mean_link_under_straggler() {
        // the headline: under a straggler, fabric-aware DeCo reaches the
        // target sooner than mean-link DeCo
        let kind = StrategyKind::DecoSgd { update_every: 20 };
        let bot = run_one(
            0.5,
            6.0,
            kind.clone(),
            PlanBasis::Bottleneck,
            4,
            512,
            6000,
        )
        .unwrap();
        let mean =
            run_one(0.5, 6.0, kind, PlanBasis::MeanLink, 4, 512, 6000).unwrap();
        let tb = bot.time_to_loss(TARGET).expect("bottleneck reaches");
        let tm = mean.time_to_loss(TARGET).expect("mean-link reaches");
        assert!(
            tb < tm,
            "bottleneck-aware {tb:.1}s should beat mean-link {tm:.1}s"
        );
    }
}
