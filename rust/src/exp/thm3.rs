//! Theorem 3 validation — sweep (a, b, δ, τ, T_comp, S_g), compare the
//! closed-form `T_avg` against the exact event recurrence, and report the
//! worst absolute deviation against the paper's `b + min{T_comp, δS_g/a}`
//! bound. Not a paper figure, but the evidence that regenerating Fig. 1 /
//! the time axes from the model is sound.

use crate::exp::results_dir;
use crate::timesim::model::{approx_error_bound, classify, t_avg_closed_form};
use crate::timesim::{EventSim, PipelineParams};

pub struct Thm3Row {
    pub p: PipelineParams,
    pub sim_tavg: f64,
    pub model_tavg: f64,
    pub abs_dev_total: f64,
    pub bound: f64,
}

pub fn sweep(iters: usize) -> Vec<Thm3Row> {
    let mut rows = Vec::new();
    for &a in &[1e7, 1e8, 5e8, 2e9] {
        for &b in &[0.01, 0.1, 0.5, 1.0] {
            for &delta in &[0.01, 0.05, 0.2, 1.0] {
                for &tau in &[0usize, 1, 2, 4, 8] {
                    for &t_comp in &[0.05, 0.35] {
                        let p = PipelineParams {
                            a,
                            b,
                            delta,
                            tau,
                            t_comp,
                            s_g: 124e6 * 32.0,
                        };
                        let sim = EventSim::run(&p, iters);
                        let model = t_avg_closed_form(&p);
                        rows.push(Thm3Row {
                            p,
                            sim_tavg: sim.t_avg(),
                            model_tavg: model,
                            abs_dev_total: (sim.total_time()
                                - iters as f64 * model)
                                .abs(),
                            bound: approx_error_bound(&p),
                        });
                    }
                }
            }
        }
    }
    rows
}

pub fn main() -> anyhow::Result<()> {
    let iters = 2000;
    let rows = sweep(iters);
    let mut worst_ratio: f64 = 0.0;
    let mut csv = String::from(
        "a,b,delta,tau,t_comp,regime,sim_tavg,model_tavg,abs_dev,bound\n",
    );
    for r in &rows {
        worst_ratio = worst_ratio.max(r.abs_dev_total / r.bound.max(1e-12));
        csv.push_str(&format!(
            "{},{},{},{},{},{:?},{:.6},{:.6},{:.6},{:.6}\n",
            r.p.a,
            r.p.b,
            r.p.delta,
            r.p.tau,
            r.p.t_comp,
            classify(&r.p),
            r.sim_tavg,
            r.model_tavg,
            r.abs_dev_total,
            r.bound
        ));
    }
    let path = results_dir().join("thm3_validation.csv");
    std::fs::write(&path, csv)?;
    println!(
        "Theorem 3 validation over {} parameter points, {iters} iters each:",
        rows.len()
    );
    println!(
        "  worst |TC_t - t*T_avg'| / (b + min(T_comp, tx)) = {worst_ratio:.3}"
    );
    println!("  (paper bound predicts O(1); anything < ~3 validates)");
    println!("wrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn deviation_within_bound_factor() {
        let rows = super::sweep(1500);
        for r in &rows {
            assert!(
                r.abs_dev_total <= 3.0 * r.bound + 1e-9,
                "{:?}: dev {} > 3x bound {}",
                r.p,
                r.abs_dev_total,
                r.bound
            );
            let rel = (r.sim_tavg - r.model_tavg).abs() / r.model_tavg;
            assert!(rel < 0.05, "{:?}: rel err {rel}", r.p);
        }
    }
}
