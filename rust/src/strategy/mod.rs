//! Training strategies — the paper's baselines (Sec. 5.1) and DeCo-SGD
//! itself, all as policies emitting `(τ_t, δ_t)` per iteration on top of the
//! same DD-EF-SGD pipeline (`coordinator::TrainLoop`). This mirrors the
//! paper's framing: every method is a point (or trajectory) in the
//! (staleness, compression) plane.
//!
//! * `DSgd` — τ=0, δ=1 (exact baseline).
//! * `DEfSgd` — τ=0, fixed δ (compression only).
//! * `DdSgd` — fixed τ, δ=1 (DGA with K=1, latency hiding only).
//! * `Accordion` — τ=0, δ switches between low/high by critical-regime
//!   detection on the gradient norm (Agarwal et al.).
//! * `CocktailSgd` — static (τ, δ) chosen once by DeCo (the paper's
//!   "DeCo-SGD with E = ∞" description of its CocktailSGD baseline).
//! * `DecoSgd` — Algorithm 2: re-run DeCo every E iterations on monitored
//!   (a, b, T_comp).

use crate::deco::{solve, DecoInput, DecoOutput};
use crate::netsim::loss::{DEFAULT_RTO_S, MAX_ATTEMPTS, MAX_BACKOFF_EXP};
use crate::netsim::FabricMonitor;
use crate::obs::{ReplanRecord, TierReplan};
use crate::timesim::{t_avg_closed_form, PipelineParams};

/// Which aggregate of the per-link monitors a strategy plans on.
///
/// On a heterogeneous [`crate::netsim::Fabric`] the synchronous aggregation
/// is gated by the slowest link, so the `(a, b)` DeCo should consume are
/// the monitored **bottleneck** (min bandwidth, max latency). `MeanLink`
/// is what a heterogeneity-blind controller sees — kept as the control arm
/// of `exp hetero`. On a homogeneous fabric the two coincide.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlanBasis {
    #[default]
    Bottleneck,
    MeanLink,
}

/// The WAN-tier planning view of a two-tier topology (DESIGN.md
/// §Topology): per-region WAN-link estimators plus the fan-in the solver
/// prices the cross-datacenter tier with.
pub struct WanCtx<'a> {
    /// number of regions — the WAN fan-in (`n_effective`): one partial
    /// flow crosses the WAN per region regardless of how many workers sit
    /// behind it. The built-in per-tier solver doesn't consume this
    /// directly — fan-in is already priced implicitly by the one-flow-per-
    /// region message sizes and the per-region clock — but fan-in-aware
    /// policies (e.g. variance-scaled δ_wan at few regions) read it here,
    /// mirroring `StrategyCtx::active_workers`.
    pub regions: usize,
    /// one estimator per *region* WAN link
    pub monitor: &'a FabricMonitor,
    /// WAN priors used before the WAN monitor has samples
    pub fallback: DecoInput,
}

impl WanCtx<'_> {
    /// Best current estimate of the WAN-tier DeCo inputs. The region
    /// partial is still a length-d aggregate, so `s_g` (not n·s_g) prices
    /// the WAN message; `t_comp` is the shared cadence partials emerge at.
    pub fn deco_input(
        &self,
        s_g: f64,
        t_comp: f64,
        plan: PlanBasis,
    ) -> DecoInput {
        let (a, b) = match plan {
            PlanBasis::Bottleneck => {
                (self.monitor.bandwidth(), self.monitor.latency())
            }
            PlanBasis::MeanLink => {
                (self.monitor.mean_bandwidth(), self.monitor.mean_latency())
            }
        };
        DecoInput {
            s_g,
            a: a.unwrap_or(self.fallback.a),
            b: b.unwrap_or(self.fallback.b),
            t_comp,
        }
    }
}

/// What a strategy can see when deciding (τ_t, δ_t).
pub struct StrategyCtx<'a> {
    pub iter: usize,
    /// per-link estimators + aggregate views (restricted to the active
    /// membership — departed workers' estimators are excluded). On a
    /// two-tier topology every worker link is an intra-region link, so
    /// this IS the LAN-tier view.
    pub monitor: &'a FabricMonitor,
    /// gradient size, bits
    pub s_g: f64,
    /// latest average gradient norm (for Accordion)
    pub grad_norm: Option<f64>,
    /// fallback network params when the monitor has no samples yet
    pub fallback: DecoInput,
    /// which monitor aggregate to plan on
    pub plan: PlanBasis,
    /// membership epoch (elastic subsystem): bumped on every churn event —
    /// leave, rejoin, drain completion, fault-window boundary, aggregator
    /// re-election. 0 forever on a static run. Event-triggered DeCo
    /// re-plans the moment it moves.
    pub membership_epoch: u64,
    /// size of the active worker set (= all workers on a static run).
    /// The built-in strategies key re-planning off the epoch alone — the
    /// network view already reflects membership through the monitor — but
    /// fan-in-aware policies (e.g. variance-scaled δ at small n) read the
    /// size here.
    pub active_workers: usize,
    /// WAN-tier planning view — `Some` iff the run prices a two-tier
    /// topology. Tier-blind strategies ignore it and their flat (τ, δ)
    /// applies to the LAN tier with the WAN tier uncompressed.
    pub wan: Option<WanCtx<'a>>,
}

/// A per-tier decision: the LAN pair every strategy emits, plus the WAN
/// pair a topology-aware strategy adds on a two-tier run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierParams {
    /// LAN-tier staleness share
    pub tau: usize,
    /// LAN-tier compression (worker → region aggregator)
    pub delta: f64,
    /// WAN tier `(τ_wan, δ_wan)` — `None` means the region partial crosses
    /// the WAN uncompressed with no extra delay share (and on a flat
    /// topology there is no WAN tier at all)
    pub wan: Option<(usize, f64)>,
    /// aggregation deadline (seconds past the sync start, DESIGN.md
    /// §Robustness): the coordinator closes the round at
    /// `min(slowest arrival, TS + deadline)` and absorbs late gradients
    /// into the stragglers' delay queues next round. `None` = wait for
    /// all arrivals (bit-identical to the historical semantics).
    pub deadline: Option<f64>,
}

impl TierParams {
    /// A tier-blind decision (flat topologies, legacy strategies).
    pub fn flat(tau: usize, delta: f64) -> Self {
        Self { tau, delta, wan: None, deadline: None }
    }

    /// End-to-end staleness the worker delay queues realize: each tier's
    /// delay share covers its own hop.
    pub fn total_tau(&self) -> usize {
        self.tau + self.wan.map_or(0, |(t, _)| t)
    }

    /// The WAN compression ratio (1.0 = uncompressed partials).
    pub fn wan_delta(&self) -> f64 {
        self.wan.map_or(1.0, |(_, d)| d)
    }
}

impl StrategyCtx<'_> {
    /// Best current estimate of the DeCo inputs under the chosen
    /// [`PlanBasis`].
    pub fn deco_input(&self) -> DecoInput {
        let (a, b) = match self.plan {
            PlanBasis::Bottleneck => {
                (self.monitor.bandwidth(), self.monitor.latency())
            }
            PlanBasis::MeanLink => {
                (self.monitor.mean_bandwidth(), self.monitor.mean_latency())
            }
        };
        DecoInput {
            s_g: self.s_g,
            a: a.unwrap_or(self.fallback.a),
            b: b.unwrap_or(self.fallback.b),
            t_comp: self
                .monitor
                .compute_time()
                .unwrap_or(self.fallback.t_comp),
        }
    }
}

/// A policy over (staleness, compression ratio).
pub trait Strategy: Send {
    fn name(&self) -> &'static str;
    /// Decide (τ, δ) for iteration `ctx.iter` (1-based).
    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64);

    /// Per-tier decision for iteration `ctx.iter`. The default wraps
    /// [`Self::params`] as a tier-blind [`TierParams`] — on a two-tier
    /// topology that ships uncompressed partials across the WAN.
    /// Topology-aware strategies (`DecoTwoTier`) override this; the
    /// training loop always calls it.
    fn params_tiered(&mut self, ctx: &StrategyCtx) -> TierParams {
        let (tau, delta) = self.params(ctx);
        TierParams::flat(tau, delta)
    }

    /// Take the decision record of the most recent re-plan, if one
    /// happened since the last call — the tracing layer's re-plan log
    /// (DESIGN.md §Observability). Static strategies never re-plan and
    /// keep the default `None`.
    fn take_replan(&mut self) -> Option<ReplanRecord> {
        None
    }
}

/// Assemble a [`ReplanRecord`] from per-tier solves: the monitor inputs
/// the solver saw, the `(τ, δ, ln φ)` it chose, Theorem 3's closed-form
/// round-time prediction at the solved LAN point, and the estimator
/// snapshot (per-slot views + pessimistic bond band) the audit layer
/// scores against ground truth (DESIGN.md §Observability → Audit).
fn replan_record(
    ctx: &StrategyCtx,
    lan_in: DecoInput,
    lan: DecoOutput,
    wan: Option<(DecoInput, DecoOutput)>,
    predicted_loss: Option<f64>,
    deadline: Option<f64>,
) -> ReplanRecord {
    let predicted_round = t_avg_closed_form(&PipelineParams {
        a: lan_in.a,
        b: lan_in.b,
        delta: lan.delta,
        tau: lan.tau,
        t_comp: lan_in.t_comp,
        s_g: lan_in.s_g,
    });
    let tier = |input: DecoInput, out: DecoOutput| TierReplan {
        input,
        tau: out.tau,
        delta: out.delta,
        log_phi: out.log_phi,
    };
    ReplanRecord {
        lan: tier(lan_in, lan),
        wan: wan.map(|(i, o)| tier(i, o)),
        predicted_round,
        pessimistic: ctx
            .monitor
            .bandwidth_pessimistic()
            .zip(ctx.monitor.latency_pessimistic()),
        links: ctx.monitor.slot_estimates(),
        predicted_loss,
        deadline,
    }
}

/// Serde-friendly strategy selector for configs / CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyKind {
    DSgd,
    DEfSgd { delta: f64 },
    DdSgd { tau: usize },
    Accordion { delta_low: f64, delta_high: f64 },
    CocktailSgd,
    DecoSgd { update_every: usize },
    /// DeCo-SGD with event-triggered re-planning: same E-boundary refresh,
    /// plus an immediate re-solve whenever the membership epoch moves
    /// (`exp churn` compares this against boundary-only `DecoSgd`).
    DecoEvent { update_every: usize },
    /// Two-tier DeCo (DESIGN.md §Topology): solve the DeCo problem once
    /// per tier — (τ_lan, δ_lan) against the worker-link view, (τ_wan,
    /// δ_wan) against the per-region WAN view — refreshed every E
    /// iterations and on every membership-epoch move (aggregator
    /// re-election included). Falls back to plain DeCo-SGD behaviour on a
    /// flat topology.
    DecoTwoTier { update_every: usize },
    /// Loss-aware DeCo (DESIGN.md §Robustness): plans on the monitored
    /// message-loss rate `p̂` by (1) inflating the effective bandwidth
    /// input `a ← a·(1−p̂)` — the `1/(1−p̂)` expected-retransmission tax —
    /// and (2) emitting a quantile-`q` aggregation deadline so one
    /// worker's retransmit tail cannot stall the round. Event-triggered
    /// like `DecoEvent`, refreshed every E iterations and on every
    /// membership-epoch move (loss bursts bump the epoch).
    DecoLossy { update_every: usize, quantile: f64 },
}

impl StrategyKind {
    pub fn build(&self) -> Box<dyn Strategy> {
        match self {
            Self::DSgd => Box::new(DSgd),
            Self::DEfSgd { delta } => Box::new(DEfSgd { delta: *delta }),
            Self::DdSgd { tau } => Box::new(DdSgd { tau: *tau }),
            Self::Accordion { delta_low, delta_high } => {
                Box::new(Accordion::new(*delta_low, *delta_high))
            }
            Self::CocktailSgd => Box::new(CocktailSgd::new()),
            Self::DecoSgd { update_every } => {
                Box::new(DecoSgd::new(*update_every))
            }
            Self::DecoEvent { update_every } => {
                Box::new(DecoSgd::event_triggered(*update_every))
            }
            Self::DecoTwoTier { update_every } => {
                Box::new(DecoTwoTier::new(*update_every))
            }
            Self::DecoLossy { update_every, quantile } => {
                Box::new(DecoLossy::new(*update_every, *quantile))
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::DSgd => "D-SGD",
            Self::DEfSgd { .. } => "D-EF-SGD",
            Self::DdSgd { .. } => "DGA",
            Self::Accordion { .. } => "Accordion",
            Self::CocktailSgd => "CocktailSGD",
            Self::DecoSgd { .. } => "DeCo-SGD",
            Self::DecoEvent { .. } => "DeCo-SGD (event)",
            Self::DecoTwoTier { .. } => "DeCo-SGD (2-tier)",
            Self::DecoLossy { .. } => "DeCo-SGD (lossy)",
        }
    }

    /// The five-method comparison set the paper's figures use.
    pub fn paper_baselines() -> Vec<StrategyKind> {
        vec![
            Self::DSgd,
            Self::Accordion { delta_low: 0.02, delta_high: 0.2 },
            Self::DdSgd { tau: 2 },
            Self::CocktailSgd,
            Self::DecoSgd { update_every: 20 },
        ]
    }
}

pub struct DSgd;

impl Strategy for DSgd {
    fn name(&self) -> &'static str {
        "D-SGD"
    }

    fn params(&mut self, _ctx: &StrategyCtx) -> (usize, f64) {
        (0, 1.0)
    }
}

pub struct DEfSgd {
    pub delta: f64,
}

impl Strategy for DEfSgd {
    fn name(&self) -> &'static str {
        "D-EF-SGD"
    }

    fn params(&mut self, _ctx: &StrategyCtx) -> (usize, f64) {
        (0, self.delta)
    }
}

pub struct DdSgd {
    pub tau: usize,
}

impl Strategy for DdSgd {
    fn name(&self) -> &'static str {
        "DGA"
    }

    fn params(&mut self, _ctx: &StrategyCtx) -> (usize, f64) {
        (self.tau, 1.0)
    }
}

/// Accordion: low compression (δ_high) inside "critical regimes" — when the
/// gradient norm is changing fast — and aggressive compression otherwise.
pub struct Accordion {
    delta_low: f64,
    delta_high: f64,
    prev_norm: Option<f64>,
    critical: bool,
    /// relative norm change that flags a critical regime
    eta: f64,
}

impl Accordion {
    pub fn new(delta_low: f64, delta_high: f64) -> Self {
        assert!(delta_low <= delta_high);
        Self { delta_low, delta_high, prev_norm: None, critical: true, eta: 0.2 }
    }
}

impl Strategy for Accordion {
    fn name(&self) -> &'static str {
        "Accordion"
    }

    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64) {
        if let Some(norm) = ctx.grad_norm {
            if let Some(prev) = self.prev_norm {
                let rel = ((norm - prev) / prev.max(1e-12)).abs();
                self.critical = rel > self.eta;
            }
            self.prev_norm = Some(norm);
        }
        let delta = if self.critical { self.delta_high } else { self.delta_low };
        (0, delta)
    }
}

/// CocktailSGD baseline per the paper's appendix: fixed (τ, δ) chosen by one
/// DeCo solve at t=1 (E = ∞).
#[derive(Default)]
pub struct CocktailSgd {
    chosen: Option<DecoOutput>,
    last_replan: Option<ReplanRecord>,
}

impl CocktailSgd {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Strategy for CocktailSgd {
    fn name(&self) -> &'static str {
        "CocktailSGD"
    }

    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64) {
        if self.chosen.is_none() {
            let input = ctx.deco_input();
            let out = solve(&input);
            self.chosen = Some(out);
            self.last_replan =
                Some(replan_record(ctx, input, out, None, None, None));
        }
        let out = self.chosen.unwrap();
        (out.tau, out.delta)
    }

    fn take_replan(&mut self) -> Option<ReplanRecord> {
        self.last_replan.take()
    }
}

/// DeCo-SGD (Algorithm 2), optionally with event-triggered re-planning on
/// membership changes (the elastic subsystem's re-planning hook).
pub struct DecoSgd {
    update_every: usize,
    current: Option<DecoOutput>,
    /// re-solve immediately when `ctx.membership_epoch` moves instead of
    /// waiting for the next `E` boundary
    event_triggered: bool,
    seen_epoch: u64,
    last_replan: Option<ReplanRecord>,
}

impl DecoSgd {
    pub fn new(update_every: usize) -> Self {
        Self {
            update_every: update_every.max(1),
            current: None,
            event_triggered: false,
            seen_epoch: 0,
            last_replan: None,
        }
    }

    /// Boundary refresh *plus* an immediate re-solve on every membership
    /// epoch change — departed stragglers stop constraining the plan the
    /// iteration after they leave, and a rejoining bottleneck is planned
    /// around at once instead of stalling every iteration until the next
    /// `E` boundary.
    pub fn event_triggered(update_every: usize) -> Self {
        Self { event_triggered: true, ..Self::new(update_every) }
    }

    pub fn current(&self) -> Option<DecoOutput> {
        self.current
    }
}

impl Strategy for DecoSgd {
    fn name(&self) -> &'static str {
        "DeCo-SGD"
    }

    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64) {
        let epoch_moved =
            self.event_triggered && ctx.membership_epoch != self.seen_epoch;
        self.seen_epoch = ctx.membership_epoch;
        // Algorithm 2: `if t mod E == 1 { τ, δ = DeCo(...) }` — extended
        // with the membership-event trigger
        if self.current.is_none()
            || ctx.iter % self.update_every == 1
            || epoch_moved
        {
            let input = ctx.deco_input();
            let out = solve(&input);
            self.current = Some(out);
            self.last_replan =
                Some(replan_record(ctx, input, out, None, None, None));
        }
        let out = self.current.unwrap();
        (out.tau, out.delta)
    }

    fn take_replan(&mut self) -> Option<ReplanRecord> {
        self.last_replan.take()
    }
}

/// Two-tier DeCo (DESIGN.md §Topology): one DeCo solve per tier, sharing
/// the `T_comp` cadence — the LAN tier against the monitored worker-link
/// view, the WAN tier against the per-region WAN view. Re-plans on the E
/// boundary and on every membership-epoch move (a departing aggregator's
/// re-election moves the epoch, so the plan follows the topology).
pub struct DecoTwoTier {
    update_every: usize,
    current: Option<TierParams>,
    seen_epoch: u64,
    last_replan: Option<ReplanRecord>,
}

impl DecoTwoTier {
    pub fn new(update_every: usize) -> Self {
        Self {
            update_every: update_every.max(1),
            current: None,
            seen_epoch: 0,
            last_replan: None,
        }
    }

    pub fn current(&self) -> Option<TierParams> {
        self.current
    }

    fn refresh_due(&mut self, ctx: &StrategyCtx) -> bool {
        let epoch_moved = ctx.membership_epoch != self.seen_epoch;
        self.seen_epoch = ctx.membership_epoch;
        self.current.is_none()
            || ctx.iter % self.update_every == 1
            || epoch_moved
    }
}

impl Strategy for DecoTwoTier {
    fn name(&self) -> &'static str {
        "DeCo-SGD (2-tier)"
    }

    /// Tier-blind fallback (flat topologies): plain event-triggered DeCo.
    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64) {
        let tp = self.params_tiered(ctx);
        (tp.total_tau(), tp.delta)
    }

    fn params_tiered(&mut self, ctx: &StrategyCtx) -> TierParams {
        if self.refresh_due(ctx) {
            let lan_in = ctx.deco_input();
            let lan = solve(&lan_in);
            let wan = ctx.wan.as_ref().map(|w| {
                let t_comp = ctx
                    .monitor
                    .compute_time()
                    .unwrap_or(ctx.fallback.t_comp);
                let wan_in = w.deco_input(ctx.s_g, t_comp, ctx.plan);
                (wan_in, solve(&wan_in))
            });
            self.current = Some(TierParams {
                tau: lan.tau,
                delta: lan.delta,
                wan: wan.map(|(_, o)| (o.tau, o.delta)),
                deadline: None,
            });
            self.last_replan =
                Some(replan_record(ctx, lan_in, lan, wan, None, None));
        }
        self.current.unwrap()
    }

    fn take_replan(&mut self) -> Option<ReplanRecord> {
        self.last_replan.take()
    }
}

/// Aggregation deadline covering the quantile-`q` retransmission tail of
/// a link with message-loss rate `p`: `A(q)` attempts of wire time (one
/// attempt = `attempt_secs`, the solved-δ transfer at the TRUE link rate)
/// plus the exponential backoff spent between them, plus half an attempt
/// of slack so the cut never lands mid-delivery of the common case.
/// `None` when `p = 0` — no loss, wait for all (the bit-identity path).
pub fn lossy_deadline(
    p: f64,
    q: f64,
    attempt_secs: f64,
    rto_s: f64,
) -> Option<f64> {
    if p <= 0.0 {
        return None;
    }
    let p = p.min(0.95);
    let q = q.clamp(0.5, 0.9999);
    // P(delivered within A attempts) = 1 − p^A ≥ q  ⇒  A ≥ ln(1−q)/ln(p)
    let a = (((1.0 - q).ln() / p.ln()).ceil().max(1.0) as u32)
        .min(MAX_ATTEMPTS);
    let mut backoff = 0.0;
    for i in 0..a.saturating_sub(1) {
        backoff += rto_s * f64::from(1u32 << i.min(MAX_BACKOFF_EXP));
    }
    Some(f64::from(a) * attempt_secs + backoff + 0.5 * attempt_secs)
}

/// Loss-aware DeCo (DESIGN.md §Robustness). Two changes over plain
/// event-triggered DeCo, both driven by the monitored loss rate `p̂`
/// ([`FabricMonitor::loss_rate`], inverted from delivered-message attempt
/// counts):
///
/// 1. **Retransmit tax** — each delivered message costs `1/(1−p̂)`
///    transmissions in expectation, so the solver sees the effective
///    goodput `a·(1−p̂)` and sizes (τ, δ) for the bandwidth the lossy
///    link actually delivers.
/// 2. **Quantile deadline** — [`lossy_deadline`] bounds the round at the
///    q-quantile of the retransmission tail; stragglers past it are
///    absorbed as +1 staleness instead of stalling every worker.
pub struct DecoLossy {
    update_every: usize,
    quantile: f64,
    current: Option<TierParams>,
    seen_epoch: u64,
    last_replan: Option<ReplanRecord>,
}

impl DecoLossy {
    pub fn new(update_every: usize, quantile: f64) -> Self {
        assert!(
            quantile > 0.0 && quantile < 1.0,
            "deadline quantile must lie in (0, 1), got {quantile}"
        );
        Self {
            update_every: update_every.max(1),
            quantile,
            current: None,
            seen_epoch: 0,
            last_replan: None,
        }
    }

    pub fn current(&self) -> Option<TierParams> {
        self.current
    }
}

impl Strategy for DecoLossy {
    fn name(&self) -> &'static str {
        "DeCo-SGD (lossy)"
    }

    fn params(&mut self, ctx: &StrategyCtx) -> (usize, f64) {
        let tp = self.params_tiered(ctx);
        (tp.tau, tp.delta)
    }

    fn params_tiered(&mut self, ctx: &StrategyCtx) -> TierParams {
        let epoch_moved = ctx.membership_epoch != self.seen_epoch;
        self.seen_epoch = ctx.membership_epoch;
        if self.current.is_none()
            || ctx.iter % self.update_every == 1
            || epoch_moved
        {
            let raw = ctx.deco_input();
            let p = ctx.monitor.loss_rate().unwrap_or(0.0).min(0.95);
            // p = 0 multiplies by exactly 1.0 — the solve input is
            // bitwise the lossless one, so the plan (and the run) is too
            let input = DecoInput { a: raw.a * (1.0 - p), ..raw };
            let out = solve(&input);
            // one attempt rides the true link rate `a`; only the
            // *expected repeat count* is a planning construct
            let attempt_secs = out.delta * raw.s_g / raw.a + raw.b;
            let deadline =
                lossy_deadline(p, self.quantile, attempt_secs, DEFAULT_RTO_S);
            self.current = Some(TierParams {
                tau: out.tau,
                delta: out.delta,
                wan: None,
                deadline,
            });
            self.last_replan = Some(replan_record(
                ctx,
                input,
                out,
                None,
                Some(p),
                deadline,
            ));
        }
        self.current.unwrap()
    }

    fn take_replan(&mut self) -> Option<ReplanRecord> {
        self.last_replan.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(monitor: &'a FabricMonitor, iter: usize) -> StrategyCtx<'a> {
        StrategyCtx {
            iter,
            monitor,
            s_g: 124e6 * 32.0,
            grad_norm: None,
            fallback: DecoInput { s_g: 124e6 * 32.0, a: 1e8, b: 0.1, t_comp: 0.5 },
            plan: PlanBasis::Bottleneck,
            membership_epoch: 0,
            active_workers: 1,
            wan: None,
        }
    }

    #[test]
    fn static_strategies() {
        let m = FabricMonitor::new(1, 0.3, 0);
        assert_eq!(DSgd.params(&ctx(&m, 1)), (0, 1.0));
        assert_eq!(DEfSgd { delta: 0.1 }.params(&ctx(&m, 1)), (0, 0.1));
        assert_eq!(DdSgd { tau: 3 }.params(&ctx(&m, 1)), (3, 1.0));
    }

    #[test]
    fn cocktail_freezes_first_solution() {
        let mut m = FabricMonitor::new(1, 0.9, 0);
        let mut s = CocktailSgd::new();
        let first = s.params(&ctx(&m, 1));
        // bandwidth collapses afterwards; cocktail must not react
        for _ in 0..50 {
            m.observe_bandwidth(1e6);
        }
        assert_eq!(s.params(&ctx(&m, 100)), first);
    }

    #[test]
    fn deco_adapts_to_bandwidth_collapse() {
        let mut m = FabricMonitor::new(1, 0.9, 0);
        for _ in 0..10 {
            m.observe_bandwidth(5e8);
            m.observe_latency(0.1);
            m.observe_compute(0.5);
        }
        let mut s = DecoSgd::new(10);
        let (_, d0) = s.params(&ctx(&m, 1));
        for _ in 0..50 {
            m.observe_bandwidth(2e7); // 25x drop
        }
        let (_, d1) = s.params(&ctx(&m, 11)); // 11 % 10 == 1 -> refresh
        assert!(d1 < d0, "delta should shrink: {d0} -> {d1}");
    }

    #[test]
    fn deco_updates_only_on_schedule() {
        let mut m = FabricMonitor::new(1, 0.9, 0);
        for _ in 0..5 {
            m.observe_bandwidth(5e8);
            m.observe_latency(0.1);
            m.observe_compute(0.5);
        }
        let mut s = DecoSgd::new(100);
        let p1 = s.params(&ctx(&m, 1));
        for _ in 0..50 {
            m.observe_bandwidth(1e6);
        }
        // iter 55: not ≡ 1 mod 100, must keep the old choice
        assert_eq!(s.params(&ctx(&m, 55)), p1);
        assert_ne!(s.params(&ctx(&m, 101)), p1);
    }

    #[test]
    fn accordion_switches_on_norm_shift() {
        let m = FabricMonitor::new(1, 0.3, 0);
        let mut s = Accordion::new(0.01, 0.5);
        let mk = |iter, norm| StrategyCtx {
            iter,
            monitor: &m,
            s_g: 1e9,
            grad_norm: Some(norm),
            fallback: DecoInput { s_g: 1e9, a: 1e8, b: 0.1, t_comp: 0.5 },
            plan: PlanBasis::Bottleneck,
            membership_epoch: 0,
            active_workers: 1,
            wan: None,
        };
        s.params(&mk(1, 10.0));
        // stable norms -> non-critical -> aggressive delta
        let (_, d) = s.params(&mk(2, 10.01));
        assert_eq!(d, 0.01);
        // sharp change -> critical -> conservative delta
        let (_, d) = s.params(&mk(3, 20.0));
        assert_eq!(d, 0.5);
    }

    #[test]
    fn kind_builds_all() {
        let mut kinds = StrategyKind::paper_baselines();
        kinds.push(StrategyKind::DecoEvent { update_every: 20 });
        kinds.push(StrategyKind::DecoTwoTier { update_every: 20 });
        kinds.push(StrategyKind::DecoLossy {
            update_every: 20,
            quantile: 0.99,
        });
        for k in kinds {
            let mut s = k.build();
            let m = FabricMonitor::new(1, 0.3, 0);
            let (tau, delta) = s.params(&ctx(&m, 1));
            assert!(delta > 0.0 && delta <= 1.0);
            assert!(tau <= 1000);
        }
    }

    #[test]
    fn event_triggered_deco_replans_on_epoch_move() {
        let mut m = FabricMonitor::new(1, 0.9, 0);
        for _ in 0..10 {
            m.observe_bandwidth(5e8);
            m.observe_latency(0.1);
            m.observe_compute(0.5);
        }
        let mut boundary = DecoSgd::new(1000);
        let mut event = DecoSgd::event_triggered(1000);
        let p0b = boundary.params(&ctx(&m, 1));
        let p0e = event.params(&ctx(&m, 1));
        assert_eq!(p0b, p0e, "identical before any epoch movement");
        // the network collapses AND a membership event fires mid-window
        for _ in 0..50 {
            m.observe_bandwidth(2e7);
        }
        let moved = StrategyCtx { membership_epoch: 1, ..ctx(&m, 55) };
        assert_eq!(
            boundary.params(&StrategyCtx { membership_epoch: 1, ..ctx(&m, 55) }),
            p0b,
            "boundary-only must wait for the E boundary"
        );
        let p1e = event.params(&moved);
        assert_ne!(p1e, p0e, "event-triggered re-plans immediately");
        // stable epoch afterwards: no extra solves (same params hold)
        assert_eq!(
            event.params(&StrategyCtx { membership_epoch: 1, ..ctx(&m, 56) }),
            p1e
        );
    }

    #[test]
    fn tier_params_compose() {
        let flat = TierParams::flat(3, 0.1);
        assert_eq!(flat.total_tau(), 3);
        assert_eq!(flat.wan_delta(), 1.0);
        let two = TierParams {
            tau: 1,
            delta: 0.5,
            wan: Some((4, 0.02)),
            deadline: None,
        };
        assert_eq!(two.total_tau(), 5);
        assert_eq!(two.wan_delta(), 0.02);
    }

    #[test]
    fn tier_blind_strategies_default_to_flat_tiers() {
        let m = FabricMonitor::new(1, 0.3, 0);
        let mut s = DdSgd { tau: 3 };
        let tp = s.params_tiered(&ctx(&m, 1));
        assert_eq!(tp, TierParams::flat(3, 1.0));
    }

    #[test]
    fn two_tier_deco_solves_each_tier_against_its_own_links() {
        // LAN: fast links (1 Gbps, 5 ms); WAN: scarce (20 Mbps, 300 ms).
        // The per-tier planner must barely compress the LAN hop and
        // compress the WAN hop hard behind a deeper delay share.
        let s_g = 2e8;
        let mut lan_m = FabricMonitor::new(4, 0.5, 0);
        let mut wan_m = FabricMonitor::new(2, 0.5, 0);
        for _ in 0..30 {
            lan_m.observe_bandwidth(1e9);
            lan_m.observe_latency(0.005);
            lan_m.observe_compute(0.2);
            wan_m.observe_bandwidth(2e7);
            wan_m.observe_latency(0.3);
        }
        let wan_fallback = DecoInput { s_g, a: 2e7, b: 0.3, t_comp: 0.2 };
        let mk = |iter| StrategyCtx {
            iter,
            monitor: &lan_m,
            s_g,
            grad_norm: None,
            fallback: DecoInput { s_g, a: 1e9, b: 0.005, t_comp: 0.2 },
            plan: PlanBasis::Bottleneck,
            membership_epoch: 0,
            active_workers: 4,
            wan: Some(WanCtx {
                regions: 2,
                monitor: &wan_m,
                fallback: wan_fallback,
            }),
        };
        let mut s = DecoTwoTier::new(100);
        let tp = s.params_tiered(&mk(1));
        let (wan_tau, wan_delta) = tp.wan.expect("two-tier plan");
        assert!(tp.delta > wan_delta, "{} vs {wan_delta}", tp.delta);
        assert!(wan_tau >= tp.tau);
        assert_eq!(tp.total_tau(), tp.tau + wan_tau);
        // between boundaries with a stable epoch the plan is frozen
        assert_eq!(s.params_tiered(&mk(50)), tp);
        // an epoch move re-plans immediately, even mid-window
        for _ in 0..50 {
            wan_m.observe_bandwidth(2e6); // WAN collapses 10x
        }
        let moved = StrategyCtx { membership_epoch: 1, ..mk(50) };
        let tp2 = s.params_tiered(&moved);
        assert!(
            tp2.wan_delta() < tp.wan_delta(),
            "{} !< {}",
            tp2.wan_delta(),
            tp.wan_delta()
        );
    }

    #[test]
    fn two_tier_deco_without_wan_ctx_matches_plain_deco() {
        let mut m = FabricMonitor::new(1, 0.9, 0);
        for _ in 0..10 {
            m.observe_bandwidth(5e8);
            m.observe_latency(0.1);
            m.observe_compute(0.5);
        }
        let mut plain = DecoSgd::new(20);
        let mut tiered = DecoTwoTier::new(20);
        let (tau_p, delta_p) = plain.params(&ctx(&m, 1));
        let tp = tiered.params_tiered(&ctx(&m, 1));
        assert_eq!(tp.wan, None, "no WAN ctx -> tier-blind plan");
        assert_eq!((tp.tau, tp.delta), (tau_p, delta_p));
    }

    #[test]
    fn lossy_deadline_quantile_math() {
        // p = 0.5, q = 0.875: 1 − 0.5^A ≥ 0.875 ⇔ A = 3 exactly.
        // Backoff between 3 attempts: rto·(1 + 2) = 3·rto.
        let c = 2.0;
        let rto = 0.2;
        let d = lossy_deadline(0.5, 0.875, c, rto).unwrap();
        assert!((d - (3.0 * c + 3.0 * rto + 0.5 * c)).abs() < 1e-12, "{d}");
        // a tighter quantile demands a longer deadline
        assert!(lossy_deadline(0.5, 0.99, c, rto).unwrap() > d);
        // heavier loss demands a longer deadline
        assert!(lossy_deadline(0.8, 0.875, c, rto).unwrap() > d);
        // lossless: no deadline at all (wait-for-all bit-identity)
        assert_eq!(lossy_deadline(0.0, 0.99, c, rto), None);
        assert_eq!(lossy_deadline(-1.0, 0.99, c, rto), None);
        // attempts stay bounded even at absurd (p, q)
        let worst = lossy_deadline(0.999, 0.9999, c, rto).unwrap();
        assert!(worst.is_finite());
    }

    #[test]
    fn lossy_deco_plans_on_the_monitored_loss_rate() {
        let mut m = FabricMonitor::new(2, 0.9, 0);
        for _ in 0..30 {
            m.observe_bandwidth(5e8);
            m.observe_latency(0.1);
            m.observe_compute(0.5);
        }
        // clean monitor: bit-identical plan to plain DeCo, no deadline
        let mut lossy = DecoLossy::new(20, 0.99);
        let mut plain = DecoSgd::new(20);
        let tp0 = lossy.params_tiered(&ctx(&m, 1));
        assert_eq!((tp0.tau, tp0.delta), plain.params(&ctx(&m, 1)));
        assert_eq!(tp0.deadline, None);
        let rec = lossy.take_replan().unwrap();
        assert_eq!(rec.predicted_loss, Some(0.0));
        assert_eq!(rec.deadline, None);
        // worker 1 starts retrying every message twice: p̂ → 0.5, and the
        // re-solve (on the epoch trigger) compresses harder against the
        // halved effective bandwidth and emits a finite deadline
        for _ in 0..200 {
            m.observe_attempts(1, 2.0);
        }
        let moved = StrategyCtx { membership_epoch: 1, ..ctx(&m, 5) };
        let tp1 = lossy.params_tiered(&moved);
        assert!(
            tp1.delta <= tp0.delta,
            "δ must not grow when goodput halves: {} -> {}",
            tp0.delta,
            tp1.delta
        );
        let d = tp1.deadline.expect("lossy plan carries a deadline");
        assert!(d.is_finite() && d > 0.0);
        let rec = lossy.take_replan().unwrap();
        let p = rec.predicted_loss.unwrap();
        assert!((p - 0.5).abs() < 1e-6, "p̂ = {p}");
        assert_eq!(rec.deadline, Some(d));
        // frozen between boundaries with a stable epoch
        assert_eq!(
            lossy.params_tiered(&StrategyCtx {
                membership_epoch: 1,
                ..ctx(&m, 6)
            }),
            tp1
        );
    }

    #[test]
    fn plan_basis_selects_monitor_aggregate() {
        // 3-link fabric with a straggler on link 0
        let mut m = FabricMonitor::new(3, 0.5, 0);
        for _ in 0..20 {
            m.observe_transfer(0, 10_000_000, 1.0); // 1e7 bps
            m.observe_transfer(1, 100_000_000, 1.0); // 1e8
            m.observe_transfer(2, 100_000_000, 1.0); // 1e8
            m.observe_latency_for(0, 0.9);
            m.observe_latency_for(1, 0.1);
            m.observe_latency_for(2, 0.1);
            m.observe_compute(0.2);
        }
        let bot = StrategyCtx { plan: PlanBasis::Bottleneck, ..ctx(&m, 1) }
            .deco_input();
        let mean = StrategyCtx { plan: PlanBasis::MeanLink, ..ctx(&m, 1) }
            .deco_input();
        assert!((bot.a - 1e7).abs() < 1.0, "bottleneck a {}", bot.a);
        assert!((bot.b - 0.9).abs() < 1e-9, "bottleneck b {}", bot.b);
        assert!((mean.a - 7e7).abs() < 1.0, "mean a {}", mean.a);
        assert!((mean.b - 1.1 / 3.0).abs() < 1e-9, "mean b {}", mean.b);
        // the mean-link planner overestimates the usable bandwidth and
        // underestimates the gating latency — the exp hetero failure mode
        assert!(mean.a > bot.a && mean.b < bot.b);
    }
}
