//! PJRT runtime benches — gradient-module execution (the L2 compute the
//! virtual clock prices as T_comp) and the AOT-lowered L1 Pallas compress
//! kernel vs the rust hot-path compressor on identical inputs.
//!
//! Skips gracefully (empty run) when `artifacts/` has not been built.

use deco::compress::{BlockTopK, Compressor};
use deco::runtime::client::BatchInput;
use deco::runtime::{default_artifacts_dir, Runtime};
use deco::util::bench::{black_box, Bench};
use deco::util::Rng;

fn main() {
    println!("== bench_runtime (PJRT grad + pallas compress) ==");
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_runtime: artifacts missing, run `make artifacts`");
        return;
    }
    let rt = Runtime::load(&dir).expect("runtime");
    let b = Bench::new("pjrt");

    // grad module execution — one training-step of the CNN
    let exec = rt.grad_exec("cnn_fmnist").expect("grad exec");
    let m = exec.model.clone();
    let params = m.init_flat(1);
    let mut rng = Rng::new(2);
    let xlen: usize = m.x_shape.iter().product();
    let x: Vec<f32> = (0..xlen).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..m.y_shape.iter().product::<usize>())
        .map(|_| rng.below(10) as i32)
        .collect();
    let mut grad = vec![0.0f32; m.param_count];
    b.bench("grad_cnn_fmnist", || {
        black_box(
            exec.run(&params, BatchInput::F32(&x), &y, &mut grad).unwrap(),
        );
    });

    // L1 pallas compress kernel (AOT) vs rust BlockTopK, same spec
    let comp = rt.compress_exec("compress_0p05").expect("compress exec");
    let dim = comp.dim;
    let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    let e: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    b.bench("pallas_compress_64k_d0.05", || {
        black_box(comp.run(&g, &e).unwrap());
    });
    let rust_comp = BlockTopK::new(0.05);
    let mut rng2 = Rng::new(3);
    let mut buf = g.clone();
    b.bench("rust_blocktopk_64k_d0.05", || {
        buf.copy_from_slice(&g);
        black_box(rust_comp.compress(&mut buf, &mut rng2));
    });

    // fused sgd apply module
    let apply = rt.apply_exec().expect("apply exec");
    let x2: Vec<f32> = (0..apply.dim).map(|_| rng.normal_f32()).collect();
    let u2: Vec<f32> = (0..apply.dim).map(|_| rng.normal_f32()).collect();
    b.bench("pallas_sgd_apply_64k", || {
        black_box(apply.run(&x2, &u2, 0.1).unwrap());
    });
}
