//! DeCo (Algorithm 1) solve cost — this runs inside the training loop every
//! E iterations, so it must be microseconds (the paper claims O(T/E) total
//! overhead, independent of n).

use deco::deco::solve::{solve, solve_brute_force, DecoInput};
use deco::util::bench::{black_box, Bench};

fn main() {
    println!("== bench_deco (Algorithm 1 solver) ==");
    let b = Bench::new("deco_solve");
    for (name, inp) in [
        ("gpt2_wan", DecoInput { s_g: 124e6 * 32.0, a: 1e8, b: 0.1, t_comp: 0.5 }),
        ("vit_wan", DecoInput { s_g: 86e6 * 32.0, a: 5e8, b: 1.0, t_comp: 0.25 }),
        (
            "extreme_latency",
            DecoInput { s_g: 124e6 * 32.0, a: 1e7, b: 2.0, t_comp: 0.05 },
        ),
    ] {
        b.bench(&format!("fast/{name}"), || {
            black_box(solve(&inp));
        });
    }
    let inp = DecoInput { s_g: 124e6 * 32.0, a: 1e8, b: 0.1, t_comp: 0.5 };
    b.bench("brute_force_400", || {
        black_box(solve_brute_force(&inp, 400));
    });
}
