//! Compression hot path — the per-iteration gradient-path cost the paper's
//! δ·S_g accounting assumes is negligible. Tracks TopK / BlockTopK / RandK /
//! Quantize selection throughput across gradient sizes plus the fused EF
//! step and the sparse codec. (In-tree harness; criterion is not in the
//! offline vendored set.)

use deco::compress::{
    BlockTopK, Compressor, ErrorFeedback, QuantizeQ8, RandK, SparseVec, TopK,
};
use deco::util::bench::{black_box, Bench};
use deco::util::Rng;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn bench_compressors() {
    let b = Bench::new("compress");
    for &n in &[65_536usize, 1 << 20, 4 << 20] {
        let base = randvec(n, 1);
        let compressors: Vec<(&str, Box<dyn Compressor>)> = vec![
            ("topk_0.05", Box::new(TopK::new(0.05))),
            ("block_topk_0.05", Box::new(BlockTopK::new(0.05))),
            ("randk_0.05", Box::new(RandK::new(0.05))),
            ("quantize_q8", Box::new(QuantizeQ8::new())),
        ];
        for (name, comp) in compressors {
            let mut rng = Rng::new(2);
            let mut buf = base.clone();
            b.bench_bytes(
                &format!("{name}/{n}"),
                (n * 4) as u64,
                || {
                    buf.copy_from_slice(&base);
                    black_box(comp.compress(&mut buf, &mut rng));
                },
            );
        }
    }
}

fn bench_ef_step() {
    let b = Bench::new("ef_step");
    for &n in &[65_536usize, 1 << 20] {
        let g = randvec(n, 3);
        let comp = TopK::new(0.05);
        let mut ef = ErrorFeedback::new(n);
        let mut rng = Rng::new(4);
        let mut buf = g.clone();
        b.bench_bytes(&format!("topk_0.05/{n}"), (n * 4) as u64, || {
            buf.copy_from_slice(&g);
            black_box(ef.step(&mut buf, &comp, &mut rng));
        });
    }
}

fn bench_sparse_codec() {
    let b = Bench::new("sparse_codec");
    let n = 1 << 20;
    let mut buf = randvec(n, 5);
    let mut rng = Rng::new(6);
    TopK::new(0.05).compress(&mut buf, &mut rng);
    b.bench_bytes("encode_1M_d0.05", (n * 4) as u64, || {
        black_box(SparseVec::encode_with_capacity(&buf, n / 20 + 1));
    });
    let sv = SparseVec::encode(&buf);
    let mut acc = vec![0.0f32; n];
    b.bench("aggregate_1M_d0.05", || {
        sv.add_into_scaled(&mut acc, 0.25);
        black_box(acc[0]);
    });
}

fn main() {
    println!("== bench_compress (gradient hot path) ==");
    bench_compressors();
    bench_ef_step();
    bench_sparse_codec();
}
