//! 100k-worker clock engine (DESIGN.md §Perf): per-tick cost of the
//! shared-timeline-class `VirtualClock` at n ∈ {1k, 10k, 100k}, against
//! the O(n) singleton-class reference engine at the sizes where the
//! reference is affordable. The `classes_*` series should be flat in n
//! (the homogeneous fabric is one class regardless of worker count) —
//! that flatness IS the tentpole claim; `reference_*` grows linearly and
//! anchors the comparison.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_scale.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;

fn fabric(n: usize) -> Fabric {
    // straggler keeps two live classes, so the incremental engine does
    // real per-tick work (two transfers + tree repairs), not a single one
    Fabric::with_straggler(n, BandwidthTrace::constant(1e8), 0.05, 0.25, 2.0)
}

fn bench_clock(b: &Bench, name: &str, make: impl Fn() -> VirtualClock) {
    let mut clock = make();
    let mut k = 0usize;
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = make();
        }
        k += 1;
        let bits = 1_000_000 + (k as u64 % 7) * 250_000;
        black_box(clock.tick(0.05, k % 4, bits));
    });
}

fn main() {
    println!("== bench_scale (shared timeline classes vs reference) ==");
    let b = Bench::new("scale");
    for &n in &[1_000usize, 10_000, 100_000] {
        bench_clock(&b, &format!("tick/classes_n{n}"), || {
            VirtualClock::new(fabric(n))
        });
    }
    // the reference engine is the pre-SoA per-worker recurrence; 100k
    // singleton ticks per bench iteration is exactly the cost the class
    // engine exists to avoid, so the reference series stops at 10k
    for &n in &[1_000usize, 10_000] {
        bench_clock(&b, &format!("tick/reference_n{n}"), || {
            VirtualClock::new(fabric(n)).with_reference_scan()
        });
    }
}
