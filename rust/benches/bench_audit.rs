//! Plan-audit fold overhead (DESIGN.md §Observability → Audit): per-tick
//! cost of the clock hot loop bare vs with the O(1) streaming `PlanAudit`
//! fold attached (a re-plan + closed-form prediction every 20 ticks, one
//! `tick()` fold per tick — the `exp scale` wiring). The fold series must
//! stay inside the untraced tick envelope; it does O(1) arithmetic and no
//! allocation per tick.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_audit.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric};
use deco::obs::PlanAudit;
use deco::timesim::{t_avg_closed_form, PipelineParams};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;
const T_COMP: f64 = 0.05;

fn fabric(n: usize) -> Fabric {
    Fabric::with_straggler(n, BandwidthTrace::constant(1e8), 0.05, 0.25, 2.0)
}

fn bench_tick(b: &Bench, name: &str, n: usize, fold: bool) {
    let mut clock = VirtualClock::new(fabric(n));
    let (a_bot, b_bot) = clock.fabric().bottleneck(0.0);
    let mut audit = PlanAudit::streaming();
    let mut k = 0usize;
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = VirtualClock::new(fabric(n));
            audit = PlanAudit::streaming();
        }
        k += 1;
        let tau = k % 4;
        let bits = 1_000_000 + (k as u64 % 7) * 250_000;
        if fold && k % 20 == 1 {
            let predicted = t_avg_closed_form(&PipelineParams {
                a: a_bot,
                b: b_bot,
                delta: 1.0,
                tau,
                t_comp: T_COMP,
                s_g: bits as f64,
            });
            audit.replan(clock.now(), k, predicted, None);
        }
        let tick = clock.tick(T_COMP, tau, bits);
        if fold {
            audit.tick(tick.tc);
        }
        black_box(tick.tc);
    });
    if fold {
        black_box(audit.summary().iters);
    }
}

fn main() {
    println!("== bench_audit (streaming plan-audit fold vs bare clock) ==");
    let b = Bench::new("audit");
    for &n in &[16usize, 1_000] {
        bench_tick(&b, &format!("tick/bare_n{n}"), n, false);
        bench_tick(&b, &format!("tick/fold_n{n}"), n, true);
    }
}
