//! Bonded-transport hot path (DESIGN.md §Bonding): the water-filling
//! `Bond::schedule` bisection at k in {2, 4} paths, and the bonded
//! virtual-clock tick versus the single-path fabric tick at
//! n in {4, 16, 32} — the per-iteration overhead multi-homing adds.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_bond.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Bond, Fabric, Link, TraceKind};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;

fn sine_link(mean: f64, lat: f64) -> Link {
    Link::new(
        BandwidthTrace::new(TraceKind::Sine {
            mean_bps: mean,
            amp_bps: 0.3 * mean,
            period_s: 7.0,
        }),
        lat,
    )
}

fn bond_of(k: usize) -> Bond {
    Bond::new(
        (0..k)
            .map(|p| sine_link(1e8 / (p + 1) as f64, 0.05 + 0.05 * p as f64))
            .collect(),
    )
}

/// A fabric with every worker k-homed on heterogeneous sine paths
/// (k = 1 leaves the plain single-link fabric untouched).
fn bonded_fabric(n: usize, k: usize) -> Fabric {
    let mut fabric = Fabric::homogeneous(
        n,
        BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 3e7,
            period_s: 7.0,
        }),
        0.05,
    );
    if k > 1 {
        for i in 0..n {
            fabric.set_bond(i, bond_of(k));
        }
    }
    fabric
}

fn bench_clock(b: &Bench, name: &str, make: impl Fn() -> VirtualClock) {
    let mut clock = make();
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = make();
        }
        black_box(clock.tick(0.05, 2, 4_000_000));
    });
}

fn main() {
    println!("== bench_bond (water-filling multi-path pricing) ==");
    let b = Bench::new("bond");
    // the scheduler alone: one water-filled transfer per call, staggered
    // path starts so the bisection sees the general case
    for &k in &[2usize, 4] {
        let bond = bond_of(k);
        let starts: Vec<f64> = (0..k).map(|p| 0.3 * p as f64).collect();
        let mut t = 0.0f64;
        b.bench(&format!("schedule/k{k}"), || {
            t = (t + 0.05) % 1000.0;
            let s: Vec<f64> = starts.iter().map(|&o| t + o).collect();
            black_box(bond.schedule(&s, 4_000_000));
        });
    }
    // the clock tick: single-path baseline, then bonded at each k — the
    // delta is what one iteration of multi-homed pricing costs
    for &n in &[4usize, 16, 32] {
        bench_clock(&b, &format!("clock_tick/single_n{n}"), move || {
            VirtualClock::new(bonded_fabric(n, 1))
        });
        for &k in &[2usize, 4] {
            bench_clock(&b, &format!("clock_tick/n{n}_k{k}"), move || {
                VirtualClock::new(bonded_fabric(n, k))
            });
        }
    }
}
