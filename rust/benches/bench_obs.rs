//! Observability overhead (DESIGN.md §Observability): per-tick cost of
//! the clock hot loop with tracing disabled (`NullSink` — the guard must
//! stay a dead branch) vs fully traced (per-worker span builds fed into
//! the streaming `Attribution`). The null series must match bench_scale's
//! untraced tick envelope — that flatness is the zero-overhead contract;
//! the traced series is O(n) by design.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_obs.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric};
use deco::obs::{
    worker_spans, Attribution, NullSink, TickTrace, TraceEvent, TraceSink,
    WorkerTrace,
};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;
const T_COMP: f64 = 0.05;

fn fabric(n: usize) -> Fabric {
    // straggler keeps two live classes so the clock does real per-tick
    // work and the traced path sees heterogeneous span boundaries
    Fabric::with_straggler(n, BandwidthTrace::constant(1e8), 0.05, 0.25, 2.0)
}

fn bench_tick(b: &Bench, name: &str, n: usize, tracer: &mut dyn TraceSink) {
    let mut clock = VirtualClock::new(fabric(n));
    let mut k = 0usize;
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = VirtualClock::new(fabric(n));
        }
        k += 1;
        let bits = 1_000_000 + (k as u64 % 7) * 250_000;
        let tick = clock.tick(T_COMP, k % 4, bits);
        if tracer.enabled() {
            let (ts, tc) = (tick.ts, tick.tc);
            let workers: Vec<WorkerTrace> = clock
                .worker_ticks()
                .iter()
                .enumerate()
                .map(|(w, wt)| {
                    let start = (wt.tm - wt.tx_secs).max(ts).min(wt.tm);
                    WorkerTrace {
                        worker: w as u32,
                        region: None,
                        aggregator: w == 0,
                        spans: worker_spans(
                            ts - T_COMP,
                            ts,
                            start,
                            wt.tm,
                            wt.tc,
                            tc,
                        ),
                        retx_secs: wt.retx_secs,
                        paths: Vec::new(),
                    }
                })
                .collect();
            tracer.record(&TraceEvent::Tick(TickTrace {
                iter: k,
                ts,
                t_comp: T_COMP,
                tc,
                workers,
                regions: Vec::new(),
            }));
        }
        black_box(tick.tc);
    });
}

fn main() {
    println!("== bench_obs (traced vs NullSink clock hot loop) ==");
    let b = Bench::new("obs");
    for &n in &[16usize, 1_000] {
        bench_tick(&b, &format!("tick/null_n{n}"), n, &mut NullSink);
        // Attribution is the O(1)-memory traced sink, so millions of
        // bench ticks never accumulate an unbounded event buffer
        let mut attr = Attribution::new();
        bench_tick(&b, &format!("tick/traced_n{n}"), n, &mut attr);
    }
}
