//! Lossy-transport pricing overhead (DESIGN.md §Robustness): per-tick
//! cost of the clock hot loop when every worker carries a message-loss
//! process — attempt-by-attempt retransmission pricing with exponential
//! backoff, i.i.d. and bursty Gilbert–Elliott — and when a binding
//! aggregation deadline adds the cut scan, against the lossless baseline
//! on the same straggler fabric. Lossy workers price as singleton
//! timeline classes, so the lossy series is O(n · attempts) by design;
//! the lossless baseline must stay inside the class-engine envelope.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_lossy.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric, LossProcess};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;
const T_COMP: f64 = 0.05;

fn fabric(n: usize, loss: Option<&LossProcess>) -> Fabric {
    // straggler keeps two live classes in the lossless baseline; with
    // loss every worker is a singleton class (per-worker draws)
    let mut f = Fabric::with_straggler(
        n,
        BandwidthTrace::constant(1e8),
        0.05,
        0.25,
        2.0,
    );
    if let Some(p) = loss {
        for w in 0..n {
            f.set_loss(w, p.clone());
        }
    }
    f
}

fn bench_tick(
    b: &Bench,
    name: &str,
    n: usize,
    loss: Option<&LossProcess>,
    deadline: Option<f64>,
) {
    let mk = || {
        let mut c = VirtualClock::new(fabric(n, loss));
        c.set_deadline(deadline);
        c
    };
    let mut clock = mk();
    let mut k = 0usize;
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = mk();
        }
        k += 1;
        let bits = 1_000_000 + (k as u64 % 7) * 250_000;
        let tick = clock.tick(T_COMP, k % 4, bits);
        black_box(tick.tc);
    });
}

fn main() {
    println!(
        "== bench_lossy (retransmission pricing + deadline cut vs \
         lossless clock hot loop) =="
    );
    let b = Bench::new("lossy");
    let iid = LossProcess::iid(0.3, 0xBE);
    let bursty = LossProcess::gilbert_elliott(0.02, 0.9, 0.1, 15.0, 0xBE);
    for &n in &[4usize, 16] {
        bench_tick(&b, &format!("tick/lossless_n{n}"), n, None, None);
        bench_tick(&b, &format!("tick/iid30_n{n}"), n, Some(&iid), None);
        bench_tick(&b, &format!("tick/bursty_n{n}"), n, Some(&bursty), None);
        // a deadline tight enough to bind on retransmit rounds, so the
        // cut scan + late-set bookkeeping is actually on the path
        bench_tick(
            &b,
            &format!("tick/iid30_deadline_n{n}"),
            n,
            Some(&iid),
            Some(0.1),
        );
    }
}
