//! Fabric hot path (DESIGN.md §Network-Fabric): `sync_arrival` across the
//! worker counts the scalability experiments use, and the fabric
//! virtual-clock tick versus the single-link clock — the per-iteration
//! overhead the pipeline pays for per-worker pricing.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_fabric.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric, Link, TraceKind};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;

fn bench_clock(b: &Bench, name: &str, make: impl Fn() -> VirtualClock) {
    let mut clock = make();
    b.bench(name, || {
        if clock.iters() >= RESET_EVERY {
            clock = make();
        }
        black_box(clock.tick(0.05, 2, 4_000_000));
    });
}

fn main() {
    println!("== bench_fabric (per-worker link pricing) ==");
    let b = Bench::new("fabric");
    for &n in &[4usize, 16, 32] {
        let fabric = Fabric::homogeneous(
            n,
            BandwidthTrace::new(TraceKind::Sine {
                mean_bps: 1e8,
                amp_bps: 3e7,
                period_s: 7.0,
            }),
            0.1,
        );
        let mut t = 0.0f64;
        b.bench(&format!("sync_arrival/n{n}"), || {
            t = (t + 0.05) % 1000.0;
            black_box(fabric.sync_arrival(t, 5_000_000));
        });
    }
    bench_clock(&b, "clock_tick/single_link", || {
        VirtualClock::single_link(Link::new(BandwidthTrace::constant(1e8), 0.1))
    });
    for &n in &[4usize, 16, 32] {
        bench_clock(&b, &format!("clock_tick/fabric_n{n}"), || {
            VirtualClock::new(Fabric::homogeneous(
                n,
                BandwidthTrace::constant(1e8),
                0.1,
            ))
        });
    }
}
