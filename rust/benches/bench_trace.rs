//! Exact prefix-integral transfer engine (DESIGN.md §Perf): the new
//! `transfer_end` / `end_of_transfer` against the pre-refactor 10 ms
//! forward-Euler stepper on the varying traces the experiments actually
//! run (Sine, OU, Markov, windowed OU) × transfer lengths {0.1 s, 3 s,
//! 30 s}, plus an end-to-end `exp hetero --fast` sweep cell with serial
//! vs pooled sweep cells.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_trace.json`. The
//! headline: the 30 s varying-trace transfer costs ~3000 `at()` calls
//! under Euler and O(log n) under the prefix engine.

use deco::exp::hetero;
use deco::netsim::{BandwidthTrace, DegradeWindow, Link, TraceKind};
use deco::util::bench::{black_box, Bench};

fn traces() -> Vec<(&'static str, BandwidthTrace)> {
    let ou = TraceKind::Ou {
        mean_bps: 1e8,
        sigma_bps: 2e7,
        theta: 0.5,
        seed: 7,
    };
    vec![
        (
            "sine",
            BandwidthTrace::new(TraceKind::Sine {
                mean_bps: 1e8,
                amp_bps: 4e7,
                period_s: 7.0,
            }),
        ),
        ("ou", BandwidthTrace::new(ou.clone())),
        (
            "markov",
            BandwidthTrace::new(TraceKind::Markov {
                levels_bps: vec![2e7, 1e8, 2e8],
                dwell_s: 2.0,
                seed: 9,
            }),
        ),
        (
            "windowed_ou",
            BandwidthTrace::new(ou).windowed(vec![
                DegradeWindow { start_s: 100.0, end_s: 115.0, frac: 0.25 },
                DegradeWindow { start_s: 400.0, end_s: 420.0, frac: 0.0 },
            ]),
        ),
    ]
}

fn main() {
    println!("== bench_trace (exact prefix-integral transfer engine) ==");
    let b = Bench::new("trace");
    // transfer lengths at the 1e8 bps mean rate
    for (label, secs) in [("0.1s", 0.1f64), ("3s", 3.0), ("30s", 30.0)] {
        let bits = (secs * 1e8) as u64;
        for (name, trace) in traces() {
            let link = Link::new(trace.clone(), 0.1);
            let mut t = 0.0f64;
            let old =
                b.bench(&format!("transfer_end_old/{name}/{label}"), || {
                    t = (t + 1.7) % 900.0;
                    black_box(trace.euler_end_reference(t, bits as f64));
                });
            let mut t = 0.0f64;
            let new =
                b.bench(&format!("transfer_end_new/{name}/{label}"), || {
                    t = (t + 1.7) % 900.0;
                    black_box(link.transfer_end(t, bits));
                });
            println!(
                "    -> speedup {name}/{label}: {:.1}x",
                old.median_ns / new.median_ns
            );
        }
    }
    // end-to-end sweep cell: the `exp hetero --fast` severity × arm grid,
    // serial cells vs cells fanned out over the worker pool (both arms use
    // prebuilt per-severity fabrics — the knob is purely the pool size)
    let (scale, workers, dim, mult) = (0.01, 4, 512, 6.0);
    let serial = b.bench("hetero_fast_sweep/serial", || {
        black_box(hetero::sweep(scale, workers, dim, mult, Some(1)).unwrap());
    });
    let pooled = b.bench("hetero_fast_sweep/pooled", || {
        black_box(hetero::sweep(scale, workers, dim, mult, None).unwrap());
    });
    println!(
        "    -> sweep speedup: {:.2}x",
        serial.median_ns / pooled.median_ns
    );
}
