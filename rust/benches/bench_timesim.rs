//! Timeline model benches — the Eq. 19 recurrence and trace-driven link
//! integration that price every training iteration (also regenerates the
//! Fig. 1 grid end-to-end to keep its cost visible).

use deco::netsim::{BandwidthTrace, Link, TraceKind};
use deco::timesim::{t_avg_closed_form, EventSim, PipelineParams};
use deco::util::bench::{black_box, Bench};

fn params() -> PipelineParams {
    PipelineParams {
        a: 1e8,
        b: 0.2,
        delta: 0.05,
        tau: 2,
        t_comp: 0.35,
        s_g: 124e6 * 32.0,
    }
}

fn main() {
    println!("== bench_timesim (Theorem 3 machinery) ==");
    let b = Bench::new("timesim");
    let p = params();
    b.bench("event_sim_10k_iters", || {
        black_box(EventSim::run(&p, 10_000).total_time());
    });
    b.bench("closed_form", || {
        black_box(t_avg_closed_form(&p));
    });
    let link = Link::new(
        BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.3,
            seed: 1,
        }),
        0.2,
    );
    b.bench("ou_trace_transfer_1k", || {
        let mut t = 0.0;
        for _ in 0..1000 {
            t = link.arrival(t, 10_000_000);
        }
        black_box(t);
    });
    b.bench("fig1_heatmap_grid", || {
        black_box(deco::exp::fig1::run(0.5, 124e6 * 32.0));
    });
}
