//! Two-tier topology hot path (DESIGN.md §Topology): the hierarchical
//! clock tick — per-member LAN pricing + per-region WAN pricing — against
//! the flat fabric tick at the worker counts the scalability experiments
//! use, across region counts. This is the per-iteration overhead the
//! pipeline pays for multi-datacenter pricing.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_topo.json`.

use deco::coordinator::VirtualClock;
use deco::netsim::{BandwidthTrace, Fabric};
use deco::topo::{RegionTopo, Topology};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;

fn lan_fabric(n: usize) -> Fabric {
    Fabric::homogeneous(n, BandwidthTrace::constant(1e9), 0.005)
}

fn two_tier(n: usize, regions: usize) -> Topology {
    assert_eq!(n % regions, 0);
    let per = n / regions;
    Topology::TwoTier {
        regions: (0..regions)
            .map(|r| RegionTopo {
                members: (r * per..(r + 1) * per).collect(),
                aggregator: r * per,
            })
            .collect(),
        wan: Fabric::homogeneous(regions, BandwidthTrace::constant(2e7), 0.3),
    }
}

fn main() {
    println!("== bench_topo (two-tier topology pricing) ==");
    let b = Bench::new("topo");
    for &n in &[4usize, 16, 32] {
        // flat baseline: the fabric tick the two-tier tick competes with
        let mut clock = VirtualClock::new(lan_fabric(n));
        b.bench(&format!("clock_tick/flat_n{n}"), || {
            if clock.iters() >= RESET_EVERY {
                clock = VirtualClock::new(lan_fabric(n));
            }
            black_box(clock.tick(0.05, 2, 4_000_000));
        });

        for &regions in &[2usize, 4] {
            if n % regions != 0 {
                continue;
            }
            let mut clock = VirtualClock::with_topology(
                lan_fabric(n),
                two_tier(n, regions),
            )
            .unwrap();
            b.bench(&format!("clock_tick/two_tier_n{n}_r{regions}"), || {
                if clock.iters() >= RESET_EVERY {
                    clock = VirtualClock::with_topology(
                        lan_fabric(n),
                        two_tier(n, regions),
                    )
                    .unwrap();
                }
                black_box(clock.tick_topo(0.05, 2, 4_000_000, 400_000, None));
            });
        }
    }

    // flat-topology delegation: the Topology::Flat wrapper must cost
    // nothing measurable over the plain tick
    let mut clock =
        VirtualClock::with_topology(lan_fabric(16), Topology::Flat).unwrap();
    b.bench("clock_tick/flat_topology_delegate_n16", || {
        if clock.iters() >= RESET_EVERY {
            clock = VirtualClock::with_topology(lan_fabric(16), Topology::Flat)
                .unwrap();
        }
        black_box(clock.tick_topo(0.05, 2, 4_000_000, 400_000, None));
    });
}
