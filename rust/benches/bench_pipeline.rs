//! End-to-end coordinator iteration — the full DD-EF-SGD hot loop (gradient
//! oracle → EF+Top-k → sparse aggregate → apply → virtual clock) on the
//! analytic quadratic oracle, isolating L3 overhead from PJRT compute.
//! One shape per paper experiment (Fig. 4 / Fig. 5 / Table 1 runs are
//! sequences of exactly these iterations).
//!
//! Each shape runs twice — `serial` (pool size 1) and `pool` (machine
//! default) — and the speedup line at the end is the parallel-engine
//! acceptance number. Steady state is allocation-free either way:
//! compressors cached per δ, gradient + sparse buffers recycled per worker.

use deco::config::{wan_network, ExperimentConfig, StopConfig};
use deco::coordinator::TrainLoop;
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::util::bench::{black_box, Bench};
use deco::util::WorkerPool;

fn run_iters(
    dim: usize,
    workers: usize,
    iters: usize,
    kind: StrategyKind,
    threads: Option<usize>,
) -> f64 {
    let oracle = Quadratic::new(dim, workers, 2.0, 0.2, 1.0, 0.5, 3);
    let cfg = ExperimentConfig {
        task: "quadratic".into(),
        workers,
        gamma: 0.2,
        strategy: kind,
        network: wan_network(1e8, 0.2, 1),
        stop: StopConfig { max_iters: iters, loss_target: None, max_virtual_time: None },
        seed: 3,
        t_comp: Some(0.05),
        s_g_bits: Some(124e6 * 32.0),
        log_every: usize::MAX, // exclude loss evals: hot loop only
        block_topk: false,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let mut params = cfg.train_params(dim);
    params.threads = threads;
    let mut tl = TrainLoop::new(oracle, cfg.strategy.build(), cfg.network.link(), params);
    tl.run("bench").total_time
}

fn main() {
    println!("== bench_pipeline (DD-EF-SGD iteration hot loop) ==");
    println!(
        "pool default: {} threads\n",
        WorkerPool::default_threads()
    );
    let b = Bench::new("pipeline");
    // fewer iterations at bigger dims keeps per-call time comparable
    let shapes: &[(usize, usize, usize)] = &[
        (4096, 4, 100),
        (65_536, 4, 50),
        (1 << 20, 4, 10),
        (65_536, 16, 25),
    ];
    let mut speedups = Vec::new();
    for &(dim, workers, iters) in shapes {
        let deco = || StrategyKind::DecoSgd { update_every: 20 };
        let bytes = (dim * 4 * workers * iters) as u64; // gradients moved
        let serial = b.bench_bytes(
            &format!("deco_{iters}iters_{workers}w_serial/{dim}"),
            bytes,
            || {
                black_box(run_iters(dim, workers, iters, deco(), Some(1)));
            },
        );
        let pooled = b.bench_bytes(
            &format!("deco_{iters}iters_{workers}w_pool/{dim}"),
            bytes,
            || {
                black_box(run_iters(dim, workers, iters, deco(), None));
            },
        );
        speedups.push((
            format!("{workers}w/{dim}"),
            serial.median_ns / pooled.median_ns,
        ));
    }
    for kind in StrategyKind::paper_baselines() {
        let label = kind.label();
        b.bench(&format!("strategies_64k/{label}"), || {
            black_box(run_iters(65_536, 4, 50, kind.clone(), None));
        });
    }
    println!("\n-- parallel speedup (serial median / pool median) --");
    for (shape, s) in &speedups {
        println!("pipeline/speedup {shape}: {s:.2}x");
    }
}
