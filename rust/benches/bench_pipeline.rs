//! End-to-end coordinator iteration — the full DD-EF-SGD hot loop (gradient
//! oracle → EF+Top-k → sparse aggregate → apply → virtual clock) on the
//! analytic quadratic oracle, isolating L3 overhead from PJRT compute.
//! One shape per paper experiment (Fig. 4 / Fig. 5 / Table 1 runs are
//! sequences of exactly these iterations).

use deco::config::{wan_network, ExperimentConfig, StopConfig};
use deco::coordinator::TrainLoop;
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::util::bench::{black_box, Bench};

fn run_iters(dim: usize, workers: usize, iters: usize, kind: StrategyKind) -> f64 {
    let oracle = Quadratic::new(dim, workers, 2.0, 0.2, 1.0, 0.5, 3);
    let cfg = ExperimentConfig {
        task: "quadratic".into(),
        workers,
        gamma: 0.2,
        strategy: kind,
        network: wan_network(1e8, 0.2, 1),
        stop: StopConfig { max_iters: iters, loss_target: None, max_virtual_time: None },
        seed: 3,
        t_comp: Some(0.05),
        s_g_bits: Some(124e6 * 32.0),
        log_every: usize::MAX, // exclude loss evals: hot loop only
        block_topk: false,
        clip_norm: Some(5.0),
    };
    let params = cfg.train_params(dim);
    let mut tl = TrainLoop::new(oracle, cfg.strategy.build(), cfg.network.link(), params);
    tl.run("bench").total_time
}

fn main() {
    println!("== bench_pipeline (DD-EF-SGD iteration hot loop) ==");
    let b = Bench::new("pipeline");
    for &dim in &[4096usize, 65_536, 1 << 20] {
        b.bench_bytes(
            &format!("deco_100iters_4w/{dim}"),
            (dim * 4 * 4 * 100) as u64, // gradients moved per measured run
            || {
                black_box(run_iters(
                    dim,
                    4,
                    100,
                    StrategyKind::DecoSgd { update_every: 20 },
                ));
            },
        );
    }
    for kind in StrategyKind::paper_baselines() {
        let label = kind.label();
        b.bench(&format!("strategies_64k/{label}"), || {
            black_box(run_iters(65_536, 4, 50, kind.clone()));
        });
    }
}
