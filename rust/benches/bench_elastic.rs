//! Elastic-membership hot path (DESIGN.md §Elasticity): the masked
//! virtual-clock tick and the membership-aware aggregation bookkeeping
//! versus the static-fabric baseline, at the worker counts the scalability
//! experiments use — the per-iteration overhead the pipeline pays for
//! dynamic membership.
//!
//! `scripts/bench.sh` consolidates these into `BENCH_elastic.json`.

use deco::coordinator::VirtualClock;
use deco::elastic::{ChurnEvent, ChurnSpec, Membership, TimedEvent};
use deco::netsim::{BandwidthTrace, Fabric};
use deco::util::bench::{black_box, Bench};

/// Rebuild the clock periodically so the TC history stays bounded while
/// the bench harness spins millions of ticks.
const RESET_EVERY: usize = 100_000;

fn fabric(n: usize) -> Fabric {
    Fabric::homogeneous(n, BandwidthTrace::constant(1e8), 0.1)
}

fn main() {
    println!("== bench_elastic (membership-aware pricing) ==");
    let b = Bench::new("elastic");
    for &n in &[4usize, 16, 32] {
        // static baseline: the all-active tick (uniform fast path)
        let mut clock = VirtualClock::new(fabric(n));
        b.bench(&format!("clock_tick/static_n{n}"), || {
            if clock.iters() >= RESET_EVERY {
                clock = VirtualClock::new(fabric(n));
            }
            black_box(clock.tick(0.05, 2, 4_000_000));
        });

        // all-active mask: the membership check without any churn
        let mut clock = VirtualClock::new(fabric(n));
        let mask = vec![true; n];
        b.bench(&format!("clock_tick/masked_all_n{n}"), || {
            if clock.iters() >= RESET_EVERY {
                clock = VirtualClock::new(fabric(n));
            }
            black_box(clock.tick_members(0.05, 2, 4_000_000, Some(&mask)));
        });

        // churned mask: one worker departed — the general per-link loop
        let mut clock = VirtualClock::new(fabric(n));
        let mut mask = vec![true; n];
        mask[0] = false;
        b.bench(&format!("clock_tick/churned_n{n}"), || {
            if clock.iters() >= RESET_EVERY {
                clock = VirtualClock::new(fabric(n));
            }
            black_box(clock.tick_members(0.05, 2, 4_000_000, Some(&mask)));
        });

        // membership bookkeeping: the per-iteration aggregation counts
        let mut m = Membership::new(n);
        m.leave(0, false);
        b.bench(&format!("membership_counts/n{n}"), || {
            black_box(m.active_count());
            black_box(m.member_count());
            black_box(m.epoch());
        });
    }

    // churn compilation cost (done once per run): a dense random schedule
    let spec = ChurnSpec::Random {
        leave_rate_per_100s: 4.0,
        mean_down_s: 20.0,
        outage_rate_per_100s: 3.0,
        outage_s: 10.0,
        horizon_s: 1000.0,
        seed: 7,
    };
    b.bench("churn_compile/random_n16", || {
        black_box(spec.compile(16).unwrap());
    });
    let scripted = ChurnSpec::Scripted {
        events: (0..64)
            .flat_map(|i| {
                let t = 10.0 * i as f64;
                [
                    TimedEvent {
                        t: t + 2.0,
                        event: ChurnEvent::Leave { worker: i % 3 },
                    },
                    TimedEvent {
                        t: t + 7.0,
                        event: ChurnEvent::Rejoin { worker: i % 3 },
                    },
                ]
            })
            .collect(),
    };
    b.bench("churn_compile/scripted_128ev_n4", || {
        black_box(scripted.compile(4).unwrap());
    });
}
