//! Theory playground: reproduce the paper's core theoretical claim on the
//! strongly-convex quadratic testbed — staleness *exponentially* amplifies
//! the damage done by gradient compression (Theorem 1's φ factor).
//!
//! ```bash
//! cargo run --release --example theory_playground
//! ```

use deco::deco::phi::phi;
use deco::exp::phi::{iters_to_target, tau_sweep};
use deco::optim::{GradOracle, Quadratic};

fn main() {
    println!("== phi(delta, tau) — the convergence-governing factor ==\n");
    println!("{:>7} {:>5} {:>14}", "delta", "tau", "phi");
    for &delta in &[0.01f64, 0.05, 0.2] {
        for &tau in &[0usize, 2, 4, 8] {
            println!("{delta:>7} {tau:>5} {:>14.2}", phi(delta, tau));
        }
    }
    println!(
        "\nnote the column ratios: phi multiplies by 1/(1-delta/2) per unit \
         of staleness\n"
    );

    println!("== steady-state excess loss on the quadratic testbed ==\n");
    let rows = tau_sweep(0.1, 0.2, 3000);
    println!("{:>7} {:>5} {:>12} {:>14}", "delta", "tau", "phi", "floor");
    for r in &rows {
        let f = if r.floor.is_finite() {
            format!("{:.6}", r.floor)
        } else {
            "diverged".into()
        };
        println!("{:>7} {:>5} {:>12.2} {:>14}", r.delta, r.tau, r.phi, f);
    }

    println!("\n== degradation sanity: tau=0 recovers D-EF-SGD speed ==");
    let mut oracle = Quadratic::new(512, 4, 0.5, 0.1, 0.3, 1.0, 31);
    let f_star = oracle.f_star();
    let l0 = {
        let x = oracle.init();
        oracle.loss(&x)
    };
    let target = f_star + 0.1 * (l0 - f_star);
    let (plain, _) =
        iters_to_target(&mut oracle, 1.0, 0, 0.1, target, 20_000);
    println!(
        "no compression, no delay: {} iterations to 10% excess",
        plain.map(|i| i.to_string()).unwrap_or_else(|| ">20000".into())
    );
}
