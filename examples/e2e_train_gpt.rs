//! End-to-end driver (the EXPERIMENTS.md §E2E run): train a GPT model with
//! real PJRT gradients for a few hundred steps on the synthetic corpus with
//! 4 workers under a varying-bandwidth WAN, logging the loss curve, and
//! compare D-SGD vs DeCo-SGD time-to-perplexity.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_gpt [-- gpt_small steps]
//! ```

use deco::config::{wan_network, ExperimentConfig, StopConfig};
use deco::exp::ExpEnv;
use deco::strategy::StrategyKind;
use anyhow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "gpt_mini".into());
    let steps: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let mut env = ExpEnv::new();

    let make = |strategy: StrategyKind| ExperimentConfig {
        task: model.clone(),
        workers: 4,
        gamma: 0.3,
        strategy,
        network: wan_network(1e8, 0.2, 42),
        stop: StopConfig {
            max_iters: steps,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 9,
        t_comp: Some(0.35),         // price like the paper's A40 step
        s_g_bits: Some(124e6 * 32.0), // price like GPT-2 124M
        log_every: 10,
        block_topk: false,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };

    println!("=== e2e: {model}, {steps} steps, 4 workers, OU WAN 100 Mbps / 200 ms ===");
    let deco_run = env.run(&make(StrategyKind::DecoSgd { update_every: 20 }))?;
    let dsgd_run = env.run(&make(StrategyKind::DSgd))?;

    println!("\nloss curves (virtual time):");
    println!("{:>6} | {:>12} {:>10} | {:>12} {:>10}", "iter", "DeCo t(s)", "loss", "D-SGD t(s)", "loss");
    for (a, b) in deco_run.records.iter().zip(&dsgd_run.records) {
        println!(
            "{:>6} | {:>12.1} {:>10.4} | {:>12.1} {:>10.4}",
            a.iter, a.time, a.loss, b.time, b.loss
        );
    }

    let target = deco_run.best_loss().max(dsgd_run.best_loss()) + 0.02;
    let td = deco_run.time_to_loss(target);
    let ts = dsgd_run.time_to_loss(target);
    println!("\nshared reachable target loss {target:.4}  (ppl {:.1})", target.exp());
    if let (Some(td), Some(ts)) = (td, ts) {
        println!(
            "time-to-target: DeCo-SGD {td:.0}s vs D-SGD {ts:.0}s -> {:.2}x speed-up",
            ts / td
        );
    }
    deco_run.write_csv("results/e2e_gpt_deco.csv")?;
    dsgd_run.write_csv("results/e2e_gpt_dsgd.csv")?;
    println!("wrote results/e2e_gpt_{{deco,dsgd}}.csv");
    Ok(())
}
