//! Quickstart: load the AOT artifacts, train the paper's CNN for a few
//! iterations with DeCo-SGD on a simulated WAN, print what DeCo chose,
//! wire two regions into a two-tier topology and show the per-tier
//! plan (DESIGN.md §Topology), ride a 2-path bonded worker through a
//! scripted path outage (DESIGN.md §Bonding), trace a 2-worker run and
//! print where its time went (DESIGN.md §Observability), audit a
//! run on a moving OU trace — predicted vs realized round times,
//! hindsight-oracle regret, and estimator calibration (§Audit) — and
//! finally push the same pair of workers through a scripted message-loss
//! burst and watch retransmissions surface as their own phase in the
//! stall-attribution table (§Robustness).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use deco::config::{
    wan_network, ExperimentConfig, FabricSpec, NetworkConfig, RegionSpec,
    StopConfig, TopologySpec,
};
use deco::coordinator::{TrainLoop, TrainParams, VirtualClock};
use deco::deco::{solve, DecoInput};
use deco::elastic::{ChurnEvent, ChurnSpec, TimedEvent};
use deco::exp::ExpEnv;
use deco::netsim::{
    BandwidthTrace, Bond, DegradeWindow, Fabric, Link, TraceKind,
};
use deco::obs::{audit_events, Attribution, TraceEvent};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::topo::{lan_input, wan_input, TwoTierPlan};
use anyhow::Result;

fn main() -> Result<()> {
    // 1. What would DeCo pick for GPT-2 on a 100 Mbps / 100 ms WAN?
    let pick = solve(&DecoInput {
        s_g: 124e6 * 32.0,
        a: 1e8,
        b: 0.1,
        t_comp: 0.5,
    });
    println!(
        "DeCo for GPT-2@(100 Mbps, 100 ms): tau*={} delta*={:.3}",
        pick.tau, pick.delta
    );

    // 2. Train the CNN end to end (real PJRT gradients, virtual WAN clock).
    let cfg = ExperimentConfig {
        task: "cnn_fmnist".into(),
        workers: 4,
        gamma: 0.05,
        strategy: StrategyKind::DecoSgd { update_every: 10 },
        network: wan_network(1e8, 0.2, 1),
        stop: StopConfig {
            max_iters: 60,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 1,
        t_comp: Some(0.04),
        s_g_bits: None,
        log_every: 10,
        block_topk: false,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let mut env = ExpEnv::new();
    let res = env.run(&cfg)?;
    println!("\niter  vtime(s)  loss      tau  delta");
    for r in &res.records {
        println!(
            "{:>4}  {:>8.1}  {:<8.4}  {:>3}  {:.3}",
            r.iter, r.time, r.loss, r.tau, r.delta
        );
    }
    println!(
        "\ntrained {} iters in {:.1}s of virtual WAN time; final loss {:.4}",
        res.total_iters,
        res.total_time,
        res.final_loss()
    );

    // 3. Two regions, one WAN: a two-tier topology run (analytic oracle —
    // fast). Each region's members push over 1 Gbps LAN links to an
    // elected aggregator; only the two δ_wan-compressed partials cross
    // the 20 Mbps / 300 ms WAN.
    let workers = 4;
    let group = |workers| RegionSpec {
        workers,
        trace: TraceKind::Constant { bps: 1e9 },
        latency_s: 0.005,
    };
    let net = NetworkConfig {
        trace: TraceKind::Constant { bps: 1e9 },
        latency_s: 0.005,
        fabric: FabricSpec::Regions { groups: vec![group(2), group(2)] },
        topology: TopologySpec::TwoTier {
            wan_trace: TraceKind::Constant { bps: 2e7 },
            wan_latency_s: 0.3,
            region_wan: Vec::new(),
        },
        bonds: Vec::new(),
        losses: Vec::new(),
    };
    let fabric = net.build_fabric(workers)?;
    let topology = net.build_topology(workers, &fabric)?;
    let (s_g, t_comp) = (1e8, 0.2);
    let plan = TwoTierPlan::solve(
        &lan_input(s_g, t_comp, &fabric, 0.0),
        &wan_input(s_g, t_comp, &topology, 0.0),
    );
    println!(
        "\ntwo-tier plan for 2 regions x 2 workers @ (LAN 1 Gbps/5 ms, \
         WAN 20 Mbps/300 ms):\n  LAN tier: tau={} delta={:.3}   WAN tier: \
         tau={} delta={:.3}   (total staleness {})",
        plan.lan.tau,
        plan.lan.delta,
        plan.wan.tau,
        plan.wan.delta,
        plan.total_tau()
    );
    let mut tl = TrainLoop::try_with_topology(
        Quadratic::new(512, workers, 0.5, 0.1, 0.3, 0.2, 7),
        StrategyKind::DecoTwoTier { update_every: 20 }.build(),
        fabric,
        topology,
        TrainParams {
            gamma: 0.02,
            max_iters: 300,
            log_every: 50,
            t_comp_override: Some(t_comp),
            s_g_override: Some(s_g),
            fallback: DecoInput { s_g, a: 1e9, b: 0.005, t_comp },
            ..Default::default()
        },
    )?;
    let res = tl.run("quadratic");
    println!("\niter  vtime(s)  loss      region syncs        wan_delta");
    for r in &res.records {
        let syncs: Vec<String> =
            r.regions.iter().map(|reg| format!("{:.1}", reg.sync)).collect();
        println!(
            "{:>4}  {:>8.1}  {:<8.4}  [{}]  {:.3}",
            r.iter,
            r.time,
            r.loss,
            syncs.join(", "),
            r.wan_delta
        );
    }
    let (wan_gbits, regions) = res
        .records
        .last()
        .map(|r| {
            let bits: u64 = r.regions.iter().map(|reg| reg.wan_bits).sum();
            (bits as f64 / 1e9, r.regions.len().max(1))
        })
        .unwrap_or((0.0, 1));
    println!(
        "\ntwo-tier run: {} iters in {:.1}s virtual; {:.2} Gbit crossed \
         the WAN (a flat star would have pushed ~{:.2} Gbit — one flow \
         per worker instead of one per region)",
        res.total_iters,
        res.total_time,
        wan_gbits,
        wan_gbits * workers as f64 / regions as f64,
    );

    // 4. Bonded failover: worker 0 is multi-homed on a fast path
    // (100 Mbps / 50 ms) plus a stable backup (20 Mbps / 250 ms), and a
    // scripted outage kills the fast path from t = 2 s to t = 8 s. The
    // water-filling scheduler shifts the bits onto the surviving path,
    // so the run degrades instead of stalling (DESIGN.md §Bonding).
    let outage = DegradeWindow { start_s: 2.0, end_s: 8.0, frac: 0.0 };
    let fast = Link::new(BandwidthTrace::constant(1e8), 0.05);
    let slow = Link::new(BandwidthTrace::constant(2e7), 0.25);
    let bond =
        Bond::new(vec![fast.clone(), slow]).with_path_windows(0, vec![outage]);
    let mut fabric =
        Fabric::homogeneous(2, BandwidthTrace::constant(1e8), 0.05);
    fabric.set_bond(0, bond);
    let mut clock = VirtualClock::new(fabric);
    let bits = 4_000_000u64;
    println!(
        "\nbonded failover (worker 0: 100 Mbps/50 ms + 20 Mbps/250 ms \
         backup; fast path out 2 s..8 s):"
    );
    println!("iter  vtime(s)  iter(s)  fast_bits  slow_bits");
    let (mut prev, mut max_gap) = (0.0f64, 0.0f64);
    for i in 0..16 {
        let t = clock.tick(0.2, 0, bits);
        let gap = t.tc - prev;
        max_gap = max_gap.max(gap);
        let paths = clock.path_ticks(0);
        let note = if paths[1].bits > paths[0].bits {
            "  <- failover: backup path carries the gradient"
        } else {
            ""
        };
        println!(
            "{:>4}  {:>8.2}  {:>7.2}  {:>9.0}  {:>9.0}{}",
            i, t.tc, gap, paths[0].bits, paths[1].bits, note
        );
        prev = t.tc;
    }
    let solo_stall = fast.with_windows(vec![outage]).arrival(2.0, bits) - 2.0;
    println!(
        "\nworst per-iteration gap {max_gap:.2}s; single-homed on the fast \
         path the same outage stalls one iteration for {solo_stall:.1}s"
    );

    // 5. Where does the time go? Trace a 2-worker WAN run and print the
    // stall-attribution report (DESIGN.md §Observability): per-phase
    // totals summing to the run's makespan, split into straggler /
    // transfer / compute fractions. The same event stream exports to
    // Chrome/Perfetto JSON via `repro trace <config>`.
    let trace_cfg = ExperimentConfig {
        task: "quadratic".into(),
        workers: 2,
        gamma: 0.02,
        strategy: StrategyKind::DecoSgd { update_every: 20 },
        network: NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 2e7 },
            0.2,
        ),
        stop: StopConfig {
            max_iters: 80,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 7,
        t_comp: Some(0.2),
        s_g_bits: Some(1e8),
        log_every: 20,
        block_topk: false,
        clip_norm: None,
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let (res, events) = ExpEnv::run_traced(&trace_cfg)?;
    let mut attr = Attribution::new();
    for ev in &events {
        if let TraceEvent::Tick(tt) = ev {
            attr.record_tick(tt);
        }
    }
    println!(
        "\nstall attribution for a 2-worker WAN run ({} iters, {:.1}s \
         makespan):\n{}",
        res.total_iters,
        attr.makespan(),
        attr.table()
    );

    // 6. Were the plans any good? Audit a 2-worker run on a *moving* OU
    // bandwidth trace (DESIGN.md §Observability → Audit): join each
    // re-plan with the virtual time it governed, re-solve each window
    // against the realized bandwidth for the hindsight-oracle regret,
    // and score the monitor's estimates against the ground-truth trace
    // means. The same report ships via `repro audit <config>`.
    let audit_cfg = ExperimentConfig {
        network: NetworkConfig::homogeneous(
            TraceKind::Ou {
                mean_bps: 2e7,
                sigma_bps: 8e6,
                theta: 0.2,
                seed: 3,
            },
            0.2,
        ),
        strategy: StrategyKind::DecoSgd { update_every: 15 },
        stop: StopConfig {
            max_iters: 90,
            loss_target: None,
            max_virtual_time: None,
        },
        ..trace_cfg
    };
    let (_, events) = ExpEnv::run_traced(&audit_cfg)?;
    let truth = audit_cfg.network.build_fabric(audit_cfg.workers)?;
    let report = audit_events(&events, &truth);
    println!(
        "\nplan audit for a 2-worker run on an OU trace (mean 20 Mbps, \
         sigma 8 Mbps):\n{}",
        report.table()
    );

    // 7. Lossy transport (DESIGN.md §Robustness): the same pair of
    // workers, but a scripted burst makes worker 0's link drop 60% of
    // its messages from t = 3 s for 40 s. Lost gradients are
    // retransmitted with exponential backoff, the loss-aware planner
    // deflates its goodput estimate and sets an aggregation deadline,
    // and the stall-attribution report grows a `retransmit` phase so
    // the episode is visible in the time budget. `repro exp lossy`
    // runs the full sweep this is a slice of.
    let lossy_cfg = ExperimentConfig {
        strategy: StrategyKind::DecoLossy { update_every: 20, quantile: 0.9 },
        network: NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 2e7 },
            0.2,
        ),
        stop: StopConfig {
            max_iters: 80,
            loss_target: None,
            max_virtual_time: None,
        },
        churn: ChurnSpec::Scripted {
            events: vec![TimedEvent {
                t: 3.0,
                event: ChurnEvent::LossBurst {
                    worker: 0,
                    rate: 0.6,
                    secs: 40.0,
                },
            }],
        },
        ..audit_cfg
    };
    let (res, events) = ExpEnv::run_traced(&lossy_cfg)?;
    let mut attr = Attribution::new();
    for ev in &events {
        if let TraceEvent::Tick(tt) = ev {
            attr.record_tick(tt);
        }
    }
    println!(
        "\nstall attribution with a scripted loss burst (worker 0 drops \
         60% of messages 3 s..43 s; {} iters, {:.1}s makespan, {:.1}% of \
         it spent retransmitting):\n{}",
        res.total_iters,
        attr.makespan(),
        attr.retransmit_fraction() * 100.0,
        attr.table()
    );
    Ok(())
}
