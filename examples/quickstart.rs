//! Quickstart: load the AOT artifacts, train the paper's CNN for a few
//! iterations with DeCo-SGD on a simulated WAN, and print what DeCo chose.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use deco::config::{wan_network, ExperimentConfig, StopConfig};
use deco::deco::{solve, DecoInput};
use deco::exp::ExpEnv;
use deco::strategy::StrategyKind;
use anyhow::Result;

fn main() -> Result<()> {
    // 1. What would DeCo pick for GPT-2 on a 100 Mbps / 100 ms WAN?
    let pick = solve(&DecoInput {
        s_g: 124e6 * 32.0,
        a: 1e8,
        b: 0.1,
        t_comp: 0.5,
    });
    println!(
        "DeCo for GPT-2@(100 Mbps, 100 ms): tau*={} delta*={:.3}",
        pick.tau, pick.delta
    );

    // 2. Train the CNN end to end (real PJRT gradients, virtual WAN clock).
    let cfg = ExperimentConfig {
        task: "cnn_fmnist".into(),
        workers: 4,
        gamma: 0.05,
        strategy: StrategyKind::DecoSgd { update_every: 10 },
        network: wan_network(1e8, 0.2, 1),
        stop: StopConfig {
            max_iters: 60,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 1,
        t_comp: Some(0.04),
        s_g_bits: None,
        log_every: 10,
        block_topk: false,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let mut env = ExpEnv::new();
    let res = env.run(&cfg)?;
    println!("\niter  vtime(s)  loss      tau  delta");
    for r in &res.records {
        println!(
            "{:>4}  {:>8.1}  {:<8.4}  {:>3}  {:.3}",
            r.iter, r.time, r.loss, r.tau, r.delta
        );
    }
    println!(
        "\ntrained {} iters in {:.1}s of virtual WAN time; final loss {:.4}",
        res.total_iters,
        res.total_time,
        res.final_loss()
    );
    Ok(())
}
