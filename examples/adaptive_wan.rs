//! Adaptive WAN training (the Fig. 6 scenario): DeCo-SGD under a
//! regime-switching bandwidth trace, printing the (bandwidth, delta, tau)
//! trajectory so you can watch the controller react to congestion episodes.
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_wan
//! ```

use deco::config::{ExperimentConfig, NetworkConfig, StopConfig};
use deco::exp::ExpEnv;
use deco::netsim::TraceKind;
use deco::strategy::StrategyKind;
use anyhow::Result;

fn main() -> Result<()> {
    let net = NetworkConfig {
        trace: TraceKind::Markov {
            levels_bps: vec![3e7, 1e8, 3e8],
            dwell_s: 20.0,
            seed: 99,
        },
        latency_s: 0.2,
    };
    let cfg = ExperimentConfig {
        task: "cnn_fmnist".into(),
        workers: 4,
        gamma: 0.05,
        strategy: StrategyKind::DecoSgd { update_every: 5 },
        network: net,
        stop: StopConfig {
            max_iters: 120,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 5,
        t_comp: Some(0.04),
        s_g_bits: Some(86e6 * 32.0), // price it like ViT-Base
        log_every: 5,
        block_topk: false,
        clip_norm: Some(5.0),
    };
    let mut env = ExpEnv::new();
    let res = env.run(&cfg)?;
    println!("DeCo-SGD under regime-switching bandwidth (30/100/300 Mbps):\n");
    println!(
        "{:>5} {:>9} {:>12} {:>7} {:>5} {:>9}",
        "iter", "vtime", "bw_est Mbps", "delta", "tau", "loss"
    );
    for r in &res.records {
        // visual bar of the chosen compression ratio
        let bar = "#".repeat((r.delta * 100.0).max(1.0) as usize / 2);
        println!(
            "{:>5} {:>9.1} {:>12.0} {:>7.3} {:>5} {:>9.4}  {bar}",
            r.iter,
            r.time,
            r.bandwidth / 1e6,
            r.delta,
            r.tau,
            r.loss
        );
    }
    println!(
        "\n{} iters, {:.0}s virtual; delta adapted across bandwidth regimes",
        res.total_iters, res.total_time
    );
    Ok(())
}
