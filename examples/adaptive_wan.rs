//! Adaptive WAN training (the Fig. 6 scenario, now on a heterogeneous
//! fabric): DeCo-SGD under a regime-switching bandwidth trace with one
//! straggler worker (half bandwidth, 2x latency). The run is priced at the
//! slowest worker's arrival, and the controller plans on the *monitored
//! bottleneck* (a, b) — watch delta/tau react to both the congestion
//! episodes and the straggler.
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_wan
//! ```

use deco::config::{ExperimentConfig, FabricSpec, NetworkConfig, StopConfig};
use deco::exp::ExpEnv;
use deco::netsim::TraceKind;
use deco::strategy::StrategyKind;
use anyhow::Result;

fn main() -> Result<()> {
    let net = NetworkConfig {
        trace: TraceKind::Markov {
            levels_bps: vec![3e7, 1e8, 3e8],
            dwell_s: 20.0,
            seed: 99,
        },
        latency_s: 0.2,
        // worker 0 is a straggler: half the bandwidth, double the latency;
        // its link gates every synchronous aggregation
        fabric: FabricSpec::Straggler { frac: 0.5, mult: 2.0 },
        topology: deco::config::TopologySpec::Flat,
        bonds: Vec::new(),
        losses: Vec::new(),
    };
    let fabric = net.build_fabric(4)?;
    let (a_bot, b_bot) = fabric.bottleneck(0.0);
    let (a_mean, b_mean) = fabric.mean(0.0);
    let cfg = ExperimentConfig {
        task: "cnn_fmnist".into(),
        workers: 4,
        gamma: 0.05,
        strategy: StrategyKind::DecoSgd { update_every: 5 },
        network: net,
        stop: StopConfig {
            max_iters: 120,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 5,
        t_comp: Some(0.04),
        s_g_bits: Some(86e6 * 32.0), // price it like ViT-Base
        log_every: 5,
        block_topk: false,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let mut env = ExpEnv::new();
    let res = env.run(&cfg)?;
    println!(
        "DeCo-SGD on a straggler fabric under regime-switching bandwidth \
         (30/100/300 Mbps):"
    );
    println!(
        "  t=0 bottleneck: {:.0} Mbps / {:.2}s   mean link: {:.0} Mbps / {:.2}s\n",
        a_bot / 1e6,
        b_bot,
        a_mean / 1e6,
        b_mean
    );
    println!(
        "{:>5} {:>9} {:>12} {:>7} {:>5} {:>9}",
        "iter", "vtime", "bw_est Mbps", "delta", "tau", "loss"
    );
    for r in &res.records {
        // visual bar of the chosen compression ratio
        let bar = "#".repeat((r.delta * 100.0).max(1.0) as usize / 2);
        println!(
            "{:>5} {:>9.1} {:>12.0} {:>7.3} {:>5} {:>9.4}  {bar}",
            r.iter,
            r.time,
            r.bandwidth / 1e6,
            r.delta,
            r.tau,
            r.loss
        );
    }
    println!(
        "\n{} iters, {:.0}s virtual; delta adapted to the monitored \
         bottleneck across bandwidth regimes",
        res.total_iters, res.total_time
    );
    Ok(())
}
