# pytest: flat-parameter machinery — offsets, padding, init distributions.
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.params import BLOCK, ParamSpec  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(shapes=st.lists(
    st.tuples(st.integers(1, 7), st.integers(1, 7)), min_size=1, max_size=8))
def test_offsets_contiguous_and_padded(shapes):
    spec = ParamSpec()
    for i, sh in enumerate(shapes):
        spec.add(f"t{i}", sh)
    spec.finalize()
    off = 0
    for t in spec.tensors:
        assert t.offset == off
        off += t.size
    assert off == spec.total
    assert spec.total % BLOCK == 0


def test_unflatten_views_match_slices():
    spec = ParamSpec()
    spec.add("a", (3, 4))
    spec.add("b", (5,), "zeros")
    spec.finalize()
    flat = np.arange(spec.total, dtype=np.float32)
    import jax.numpy as jnp

    views = spec.unflatten(jnp.asarray(flat))
    np.testing.assert_array_equal(
        np.asarray(views["a"]).ravel(), flat[:12])
    np.testing.assert_array_equal(np.asarray(views["b"]), flat[12:17])
    assert "_pad" not in views


def test_init_distributions():
    spec = ParamSpec()
    spec.add("w", (100, 100), "normal", std=0.3)
    spec.add("g", (64,), "ones")
    spec.add("b", (64,), "zeros")
    spec.finalize()
    flat = spec.init_flat(7)
    w = flat[:10000]
    assert abs(float(np.std(w)) - 0.3) < 0.02
    assert (flat[10000:10064] == 1.0).all()
    assert (flat[10064:10128] == 0.0).all()
    # pad stays zero
    pad = [t for t in spec.tensors if t.name == "_pad"][0]
    assert not flat[pad.offset:].any()


def test_default_std_is_fan_in_scaled():
    spec = ParamSpec()
    spec.add("w", (64, 32))
    spec.finalize()
    t = spec.tensors[0]
    assert abs(t.std - 1 / np.sqrt(64)) < 1e-9
