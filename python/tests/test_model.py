# pytest: L2 model correctness — shapes, analytic grad vs numerical diff,
# flat-parameter layout, loss behaviour under a few SGD steps.
from __future__ import annotations

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import model as model_mod  # noqa: E402
from compile.params import BLOCK, ParamSpec  # noqa: E402

REG = model_mod.build_registry()
SMALL = ["cnn_fmnist", "vit_tiny", "gpt_mini"]


def _batch(mdef, seed=0):
    rng = np.random.default_rng(seed)
    if mdef.x_dtype == "f32":
        x = rng.standard_normal(mdef.x_shape).astype(np.float32)
        y = rng.integers(0, mdef.meta["classes"], mdef.y_shape).astype(np.int32)
    else:
        x = rng.integers(0, mdef.meta["vocab"], mdef.x_shape).astype(np.int32)
        y = rng.integers(0, mdef.meta["vocab"], mdef.y_shape).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", SMALL)
def test_shapes_and_finite(name):
    mdef = REG[name]
    flat = jnp.asarray(mdef.spec.init_flat(0))
    x, y = _batch(mdef)
    loss, grad = mdef.loss_and_grad(flat, x, y)
    assert loss.shape == ()
    assert grad.shape == (mdef.spec.total,)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(grad)).all()


@pytest.mark.parametrize("name", SMALL)
def test_param_count_padded(name):
    mdef = REG[name]
    assert mdef.spec.total % BLOCK == 0
    # offsets are contiguous and non-overlapping
    off = 0
    for t in mdef.spec.tensors:
        assert t.offset == off
        off += t.size
    assert off == mdef.spec.total


@pytest.mark.parametrize("name", SMALL)
def test_pad_gradient_is_zero(name):
    """The padding tail must never receive gradient."""
    mdef = REG[name]
    pad = [t for t in mdef.spec.tensors if t.name == "_pad"]
    if not pad:
        pytest.skip("model size is an exact BLOCK multiple")
    flat = jnp.asarray(mdef.spec.init_flat(1))
    x, y = _batch(mdef, 1)
    _, grad = mdef.loss_and_grad(flat, x, y)
    tail = np.asarray(grad)[pad[0].offset:]
    assert not tail.any()


@pytest.mark.parametrize("name", ["cnn_fmnist", "gpt_mini"])
def test_grad_matches_numerical(name):
    mdef = REG[name]
    flat = mdef.spec.init_flat(2)
    x, y = _batch(mdef, 2)

    def loss_fn(f, xx, yy):
        loss, _ = mdef.loss_and_grad(jnp.asarray(f), xx, yy)
        return loss

    _, grad = mdef.loss_and_grad(jnp.asarray(flat), x, y)
    grad = np.asarray(grad)
    rng = np.random.default_rng(3)
    # probe a few non-pad coordinates with non-trivial gradient
    nz = np.nonzero(np.abs(grad) > 1e-4)[0]
    idx = rng.choice(nz, size=min(6, len(nz)), replace=False)
    num = model_mod.numerical_grad(loss_fn, flat, x, y, idx)
    np.testing.assert_allclose(grad[idx], num, rtol=0.08, atol=2e-3)


@pytest.mark.parametrize("name", SMALL)
def test_loss_decreases_under_sgd(name):
    mdef = REG[name]
    flat = jnp.asarray(mdef.spec.init_flat(4))
    x, y = _batch(mdef, 4)
    step = jax.jit(lambda f: mdef.loss_and_grad(f, x, y))
    l0, g = step(flat)
    lr = 0.05
    for _ in range(20):
        flat = flat - lr * g
        loss, g = step(flat)
    assert float(loss) < float(l0)


def test_cross_entropy_uniform():
    """CE of uniform logits == log(C)."""
    logits = jnp.zeros((7, 10))
    y = jnp.arange(7, dtype=jnp.int32) % 10
    assert abs(float(model_mod.cross_entropy(logits, y)) - np.log(10)) < 1e-5


def test_attention_causality():
    """Future tokens must not influence past positions in the GPT."""
    mdef = REG["gpt_mini"]
    flat = jnp.asarray(mdef.spec.init_flat(5))
    rng = np.random.default_rng(5)
    vocab = mdef.meta["vocab"]
    t1 = rng.integers(0, vocab, mdef.x_shape).astype(np.int32)
    t2 = t1.copy()
    t2[:, -1] = (t2[:, -1] + 1) % vocab  # perturb only the last token
    from compile.model import GptConfig, gpt_forward

    cfg = GptConfig(vocab=vocab, seq=mdef.meta["seq"],
                    d_model=mdef.meta["d_model"],
                    n_layer=mdef.meta["n_layer"], n_head=4, ff=512)
    l1 = gpt_forward(cfg, mdef.spec, flat, jnp.asarray(t1))
    l2 = gpt_forward(cfg, mdef.spec, flat, jnp.asarray(t2))
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_init_flat_deterministic():
    spec = ParamSpec()
    spec.add("w", (8, 8))
    spec.add("b", (8,), "zeros")
    spec.finalize()
    a, b = spec.init_flat(9), spec.init_flat(9)
    np.testing.assert_array_equal(a, b)
    assert spec.total % BLOCK == 0
