# pytest: Pallas kernels vs pure-jnp ref — the CORE correctness signal.
#
# hypothesis sweeps shapes / deltas / seeds and asserts the pallas kernel
# matches the ref oracle bit-for-bit (both are deterministic specs), plus the
# paper's compressor contract (Lemma 2) and the EF bookkeeping invariant.
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import ref, sgd_apply, topk_ef  # noqa: E402
from compile.params import BLOCK  # noqa: E402


def _rand(n: int, seed: int, scale: float = 1.0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# pallas kernel vs ref oracle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 6),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    block_pow=st.integers(5, 9),  # block in {32 .. 512}
)
def test_pallas_matches_ref(nblocks, k, seed, block_pow):
    block = 2 ** block_pow
    k = min(k, block)
    d = nblocks * block
    g, e = _rand(d, seed), _rand(d, seed + 1, 0.5)
    d_pl, e_pl = topk_ef.compress_ef(g, e, k=k, block=block)
    d_rf, e_rf = ref.compress_ef_ref(g, e, block, k)
    np.testing.assert_array_equal(np.asarray(d_pl), np.asarray(d_rf))
    np.testing.assert_array_equal(np.asarray(e_pl), np.asarray(e_rf))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, BLOCK))
def test_nnz_exactly_k_per_block(seed, k):
    d = 4 * BLOCK
    g, e = _rand(d, seed), _rand(d, seed + 7)
    delta, _ = topk_ef.compress_ef(g, e, k=k)
    nz = (np.asarray(delta).reshape(-1, BLOCK) != 0).sum(axis=1)
    # ties at zero can only reduce the count below k if the block has zeros
    assert (nz <= k).all()
    assert (nz == k).all() or float(np.abs(np.asarray(g + e)).min()) == 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 128))
def test_ef_invariant_and_lemma2(seed, k):
    """delta + e_new == g + e exactly, and ||C(a)-a||^2 <= (1-k/B)||a||^2."""
    d = 2 * BLOCK
    g, e = _rand(d, seed), _rand(d, seed + 3)
    delta, e_new = topk_ef.compress_ef(g, e, k=k)
    a = np.asarray(g + e, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(delta) + np.asarray(e_new), a)
    # Lemma 2 with the blockwise ratio k/BLOCK
    lhs = float(np.sum(np.asarray(e_new) ** 2))
    rhs = (1.0 - k / BLOCK) * float(np.sum(a.astype(np.float64) ** 2))
    assert lhs <= rhs + 1e-4


def test_selected_are_largest():
    g = _rand(BLOCK, 42)
    e = jnp.zeros_like(g)
    k = 33
    delta, _ = topk_ef.compress_ef(g, e, k=k)
    kept = np.abs(np.asarray(delta))
    dropped_max = np.abs(np.asarray(g))[kept == 0].max()
    kept_min = kept[kept > 0].min()
    assert kept_min >= dropped_max


def test_tie_break_lower_index_wins():
    """All-equal magnitudes: the FIRST k must be selected."""
    a = jnp.ones(BLOCK, dtype=jnp.float32)
    delta, _ = topk_ef.compress_ef(a, jnp.zeros_like(a), k=10)
    nz = np.nonzero(np.asarray(delta))[0]
    np.testing.assert_array_equal(nz, np.arange(10))


def test_k_full_block_is_identity():
    g = _rand(BLOCK, 5)
    e = _rand(BLOCK, 6)
    delta, e_new = topk_ef.compress_ef(g, e, k=BLOCK)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(g + e))
    assert not np.asarray(e_new).any()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3),
       lr=st.floats(1e-5, 1.0))
def test_sgd_apply_matches_ref(seed, scale, lr):
    d = 2 * BLOCK
    x, u = _rand(d, seed, scale), _rand(d, seed + 1, scale)
    out = sgd_apply.sgd_apply(x, u, jnp.asarray([lr], jnp.float32))
    # one f32 ULP of slack: interpret-mode fuses the mul-sub differently
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.sgd_apply_ref(x, u, np.float32(lr))),
        rtol=2e-7 * 8, atol=1e-6 * scale)


# ---------------------------------------------------------------------------
# exact (global) top-k oracle sanity — the spec rust's production path uses
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_exact_topk_ref_properties(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(n).astype(np.float32)
    k = max(1, n // 7)
    out = ref.exact_topk_ref(a, k)
    nz = np.nonzero(out)[0]
    assert len(nz) == min(k, n)
    # every kept magnitude >= every dropped magnitude
    if len(nz) < n:
        assert np.abs(out[nz]).min() >= np.abs(a[out == 0]).max() - 0.0
    # kept values pass through unchanged
    np.testing.assert_array_equal(out[nz], a[nz])


def test_k_for_delta():
    assert topk_ef.k_for_delta(1.0) == BLOCK
    assert topk_ef.k_for_delta(0.5) == BLOCK // 2
    assert topk_ef.k_for_delta(1e-9) == 1  # floor at 1
    assert topk_ef.k_for_delta(0.05) == 52  # ceil(0.05*1024)
