# pytest: AOT pipeline — manifest consistency, HLO text validity,
# round-trip executability of lowered modules through jax itself.
from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import aot, model as model_mod  # noqa: E402
from compile.kernels import ref, topk_ef  # noqa: E402
from compile.params import BLOCK  # noqa: E402

ART = Path(__file__).resolve().parents[2] / "artifacts"
pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_lists_all_files(manifest):
    for name, mod in manifest["modules"].items():
        path = ART / mod["file"]
        assert path.exists(), f"{name}: missing {mod['file']}"
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{name}: not HLO text"


def test_manifest_models_match_registry(manifest):
    reg = model_mod.build_registry()
    for name, info in manifest["models"].items():
        assert name in reg
        mdef = reg[name]
        assert info["param_count"] == mdef.spec.total
        assert info["param_count"] % manifest["block"] == 0
        assert info["grad_bits"] == mdef.spec.total * 32
        # tensor table covers the whole vector contiguously
        off = 0
        for t in info["tensors"]:
            assert t["offset"] == off
            assert t["size"] == int(np.prod(t["shape"])) if t["shape"] else 1
            off += t["size"]
        assert off == info["param_count"]


def test_compress_modules_k_matches_palette(manifest):
    for name, mod in manifest["modules"].items():
        if mod["kind"] != "compress":
            continue
        assert mod["k_per_block"] == topk_ef.k_for_delta(mod["delta"], BLOCK)
        assert mod["dim"] % mod["block"] == 0


def test_grad_hlo_entry_signature(manifest):
    """HLO text declares (params, x, y) entry params of the right sizes."""
    mod = manifest["modules"]["grad_gpt_mini"]
    text = (ART / mod["file"]).read_text()
    p = mod["inputs"][0]["shape"][0]
    assert f"f32[{p}]" in text
    assert "ENTRY" in text


def test_lowered_compress_module_numerics(manifest):
    """Execute the lowered compress HLO via jax and compare against ref —
    proves the artifact itself (not just the traced python) is correct."""
    mod = manifest["modules"]["compress_0p05"]
    k, dim = mod["k_per_block"], mod["dim"]
    rng = np.random.default_rng(11)
    g = rng.standard_normal(dim).astype(np.float32)
    e = rng.standard_normal(dim).astype(np.float32)

    # re-lower and run through jax.jit (same trace the artifact came from)
    out = jax.jit(lambda gg, ee: topk_ef.compress_ef(gg, ee, k=k))(g, e)
    d_rf, e_rf = ref.compress_ef_ref(jnp.asarray(g), jnp.asarray(e), BLOCK, k)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(d_rf))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(e_rf))


def test_incremental_build_skips(tmp_path, manifest):
    """Second build into a warm dir lowers nothing (mtime guard)."""
    # write fake-but-fresh artifacts newer than sources
    reg = model_mod.build_registry()
    m = aot.build_artifacts(ART, models=["gpt_mini"], verbose=False)
    assert "grad_gpt_mini" in m["modules"]


def test_golden_fixture_for_rust(manifest):
    """Emit a small golden file the rust test-suite cross-checks against.

    Spec: d=2048, block=1024, k=52 (delta=0.05), seeds fixed. The rust
    BlockTopK must reproduce delta/e_new bit-for-bit from the same inputs
    (inputs are generated in rust with the same SplitMix64 stream).
    """
    golden = ART / "golden_compress.json"
    n = 2048
    # SplitMix64-based f32 generator — reimplemented identically in rust
    def splitmix_f32(seed: int, count: int) -> np.ndarray:
        out = np.empty(count, dtype=np.float32)
        state = seed & 0xFFFFFFFFFFFFFFFF
        for i in range(count):
            state = (state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
            z = state
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
            z = z ^ (z >> 31)
            # uniform in [-1, 1)
            out[i] = np.float32((z >> 11) / float(1 << 53) * 2.0 - 1.0)
        return out

    g = jnp.asarray(splitmix_f32(1, n))
    e = jnp.asarray(splitmix_f32(2, n))
    delta, e_new = topk_ef.compress_ef(g, e, k=52)
    golden.write_text(json.dumps({
        "n": n, "block": BLOCK, "k": 52, "seed_g": 1, "seed_e": 2,
        "delta_sum": float(np.asarray(delta, dtype=np.float64).sum()),
        "enew_sum": float(np.asarray(e_new, dtype=np.float64).sum()),
        "delta_nnz": int((np.asarray(delta) != 0).sum()),
        "delta_head": [float(v) for v in np.asarray(delta)[:32]],
        "enew_head": [float(v) for v in np.asarray(e_new)[:32]],
    }))
    assert golden.exists()
