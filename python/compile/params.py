"""Flat-parameter machinery shared by all L2 models.

Every model exposes its parameters as ONE contiguous f32[P] vector (padded to
a multiple of BLOCK so the L1 blockwise compressor never needs a remainder
path). The rust coordinator only ever sees that flat vector: it owns the
parameter buffer, receives flat gradients from the PJRT `grad_*` modules, and
runs compression / error-feedback / SGD on flat f32 slices.

The spec (tensor name, shape, offset, init) is serialized into
artifacts/manifest.json so rust can initialize parameters itself without any
python at runtime.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

# Block size of the L1 blockwise compressor; flat params are padded to a
# multiple of this so every module in the stack agrees on sizes.
BLOCK = 1024


@dataclass
class TensorSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # "normal" | "zeros" | "ones"
    std: float = 0.0
    offset: int = 0

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass
class ParamSpec:
    tensors: List[TensorSpec] = field(default_factory=list)

    def add(self, name: str, shape: Tuple[int, ...], init: str = "normal",
            std: float | None = None) -> None:
        if std is None:
            # fan-in scaled init by default
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else (shape[0] if shape else 1)
            std = 1.0 / math.sqrt(max(fan_in, 1))
        self.tensors.append(TensorSpec(name, tuple(shape), init, float(std)))

    def finalize(self) -> "ParamSpec":
        """Assign offsets and append a pad tensor up to a BLOCK multiple."""
        off = 0
        for t in self.tensors:
            t.offset = off
            off += t.size
        pad = (-off) % BLOCK
        if pad:
            t = TensorSpec("_pad", (pad,), "zeros", 0.0, off)
            self.tensors.append(t)
            off += pad
        self._total = off
        self._index = {t.name: t for t in self.tensors}
        return self

    @property
    def total(self) -> int:
        return self._total

    def slice(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        t = self._index[name]
        return flat[t.offset:t.offset + t.size].reshape(t.shape)

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return {t.name: self.slice(flat, t.name) for t in self.tensors
                if t.name != "_pad"}

    def init_flat(self, seed: int) -> np.ndarray:
        """Numpy init (used by tests; rust re-implements from the manifest)."""
        rng = np.random.default_rng(seed)
        out = np.zeros(self.total, dtype=np.float32)
        for t in self.tensors:
            if t.init == "normal":
                out[t.offset:t.offset + t.size] = (
                    rng.standard_normal(t.size).astype(np.float32) * t.std)
            elif t.init == "ones":
                out[t.offset:t.offset + t.size] = 1.0
            # zeros: already zero
        return out

    def to_manifest(self) -> List[dict]:
        return [
            {"name": t.name, "shape": list(t.shape), "offset": t.offset,
             "size": t.size, "init": t.init, "std": t.std}
            for t in self.tensors
        ]
