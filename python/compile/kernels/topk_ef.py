"""L1 Pallas kernel: fused blockwise Top-k + error-feedback compression.

This is the paper's gradient-compression hot-spot (`Delta = C_delta(g + e)`,
`e' = (g + e) - Delta`, Sec. 2.2.2) expressed as a single Pallas kernel so the
error-compensated gradient streams through VMEM exactly once per block.

TPU adaptation of the usual GPU Top-k (see DESIGN.md §Hardware-Adaptation):
GPU implementations radix-select across warps with per-thread scatters; the
TPU has no scatter unit, so we tile the flat gradient into VMEM-sized blocks
(BlockSpec over a 1-D grid) and compute a *threshold mask* per block with
vector-unit-friendly ops (sort, compare, cumsum) instead of data movement.
`k` is a compile-time constant (one artifact per palette delta — see aot.py);
the selection rule matches kernels/ref.py (and the rust `BlockTopK`) exactly,
including the lower-index-wins tie-break, so all three implementations are
bit-identical.

interpret=True is mandatory on this image: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget note (see DESIGN.md §Perf / EXPERIMENTS.md §Perf): per grid step
# the kernel holds g, e, a, |a|, the sorted copy, and the two outputs in VMEM:
# 7 * BLOCK * 4 B. With BLOCK = 1024 that is 28 KiB — far under the ~16 MiB
# VMEM of a TPU core, leaving room for the compiler to double-buffer the
# HBM->VMEM pipeline across grid steps. Larger BLOCK (8-64K) amortizes grid
# overhead; BLOCK=1024 is chosen to match the rust hot path's cache tiling.
DEFAULT_BLOCK = 1024


def _topk_ef_kernel(g_ref, e_ref, delta_ref, enew_ref, *, k: int):
    """One block: select k largest |g+e|, emit transmitted part + new error."""
    a = g_ref[...] + e_ref[...]
    absa = jnp.abs(a)
    n = absa.shape[0]
    if k >= n:
        delta_ref[...] = a
        enew_ref[...] = jnp.zeros_like(a)
        return
    # Threshold = k-th largest |a|; sort is the TPU-friendly selection
    # primitive (vectorized bitonic under the hood, no scatters).
    thr = jnp.sort(absa)[n - k]
    gt = absa > thr
    n_gt = jnp.sum(gt)
    eq = absa == thr
    # lower-index-wins tie-break: keep the first (k - n_gt) ties
    sel_eq = eq & (jnp.cumsum(eq) <= k - n_gt)
    mask = gt | sel_eq
    delta = jnp.where(mask, a, 0.0)
    delta_ref[...] = delta
    enew_ref[...] = a - delta


def compress_ef(g: jnp.ndarray, e: jnp.ndarray, *, k: int,
                block: int = DEFAULT_BLOCK) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused blockwise top-k EF compress over flat f32[d], d % block == 0.

    Returns (delta, e_new). delta has exactly min(k, block) non-zeros per
    block; the achieved compression ratio is k/block.
    """
    d = g.shape[0]
    assert d % block == 0, f"d={d} must be a multiple of block={block}"
    grid = (d // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = [
        jax.ShapeDtypeStruct((d,), g.dtype),
        jax.ShapeDtypeStruct((d,), g.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, k=k),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(g, e)


def k_for_delta(delta: float, block: int = DEFAULT_BLOCK) -> int:
    """Per-block k for a target compression ratio delta (ceil, >= 1)."""
    import math

    return max(1, min(block, math.ceil(delta * block)))
