"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These define the *specification* the Pallas kernels (and the rust hot-path
reimplementation in `rust/src/compress/`) must match bit-for-bit:

Blockwise Top-k error-feedback compression (the paper's `C_delta` + EF fused):
    a      = g + e                  (error-compensated gradient)
    keep the k largest |a| per block of size BLOCK, ties broken by LOWER
    index first (deterministic, so rust/jax/pallas agree exactly)
    delta  = a * mask               (what gets transmitted)
    e_new  = a - delta              (error carried to the next round)

Tie-break spec: with thr = k-th largest |a| in the block,
    * every |a| >  thr is selected;
    * of the entries with |a| == thr, the first (k - #gt) in index order.

Fused SGD apply:  x_new = x - lr * upd.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def topk_mask_1d(absa: jnp.ndarray, k: int) -> jnp.ndarray:
    """Deterministic top-k mask over a 1-D block, lower index wins ties."""
    n = absa.shape[0]
    if k >= n:
        return jnp.ones_like(absa, dtype=bool)
    # threshold = k-th largest value
    thr = jnp.sort(absa)[n - k]
    gt = absa > thr
    n_gt = jnp.sum(gt)
    eq = absa == thr
    take_eq = k - n_gt  # how many ties we may keep
    eq_rank = jnp.cumsum(eq)  # 1-based rank among ties
    sel_eq = eq & (eq_rank <= take_eq)
    return gt | sel_eq


def compress_ef_ref(g: jnp.ndarray, e: jnp.ndarray, block: int, k: int):
    """Reference blockwise top-k EF compression over flat f32[d]."""
    d = g.shape[0]
    assert d % block == 0, "flat length must be padded to a block multiple"
    a = g + e
    ab = a.reshape(d // block, block)
    absa = jnp.abs(ab)
    masks = jnp.stack([topk_mask_1d(absa[i], k) for i in range(d // block)])
    delta = jnp.where(masks, ab, 0.0).reshape(d)
    e_new = a - delta
    return delta, e_new


def compress_ef_ref_vmap(g: jnp.ndarray, e: jnp.ndarray, block: int, k: int):
    """Same spec, vectorized over blocks (used as the L2 jax path)."""
    import jax

    d = g.shape[0]
    a = g + e
    ab = a.reshape(d // block, block)
    masks = jax.vmap(lambda row: topk_mask_1d(jnp.abs(row), k))(ab)
    delta = jnp.where(masks, ab, 0.0).reshape(d)
    return delta, a - delta


def exact_topk_ref(a: np.ndarray, k: int) -> np.ndarray:
    """Global (non-blockwise) exact top-k with the same tie-break, numpy.

    The oracle the rust `compress::topk` production path is tested against.
    """
    n = a.shape[0]
    if k >= n:
        return a.copy()
    absa = np.abs(a)
    # argsort on (-|a|, index) == stable sort of -|a|
    order = np.argsort(-absa, kind="stable")
    keep = order[:k]
    out = np.zeros_like(a)
    out[keep] = a[keep]
    return out


def sgd_apply_ref(x: jnp.ndarray, upd: jnp.ndarray, lr: float) -> jnp.ndarray:
    return x - lr * upd
