"""L1 Pallas kernel: fused SGD parameter update `x' = x - lr * upd`.

Trivial arithmetic, but expressing it as a Pallas kernel keeps the whole
apply step a single pass over HBM (read x, read upd, write x') instead of a
scaled-mul temporary + subtract — the same fusion XLA would need a fusion
pass to discover. lr arrives as a (1,)-shaped operand broadcast to every
block (scalars-as-arrays is the portable pattern under interpret=True).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _sgd_apply_kernel(x_ref, upd_ref, lr_ref, out_ref):
    out_ref[...] = x_ref[...] - lr_ref[0] * upd_ref[...]


def sgd_apply(x: jnp.ndarray, upd: jnp.ndarray, lr: jnp.ndarray,
              *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """x, upd: f32[d] with d % block == 0; lr: f32[1]. Returns f32[d]."""
    d = x.shape[0]
    assert d % block == 0, f"d={d} must be a multiple of block={block}"
    spec = pl.BlockSpec((block,), lambda i: (i,))
    lr_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _sgd_apply_kernel,
        grid=(d // block,),
        in_specs=[spec, spec, lr_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, upd, lr)
