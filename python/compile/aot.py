"""AOT compile path: lower every L2/L1 module to HLO *text* + manifest.json.

Run once via `make artifacts`; python is never on the rust hot path. Emits:

  artifacts/grad_<model>.hlo.txt      (params, x, y) -> (loss, grad)
  artifacts/compress_<d>_<delta>.hlo.txt  (g, e) -> (delta_vec, e_new)
                                      pallas blockwise top-k EF, palette delta
  artifacts/sgd_apply_<d>.hlo.txt     (x, upd, lr[1]) -> (x_new)
  artifacts/manifest.json             module table + per-model tensor layout

HLO TEXT is the interchange format, NOT `.serialize()` / StableHLO bytes: the
xla crate's bundled xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit
instruction ids); the text parser reassigns ids. We lower through
stablehlo -> XlaComputation -> as_hlo_text with return_tuple=True, matching
/opt/xla-example/gen_hlo.py, and unwrap with to_tuple<N>() in rust.

Incremental: a module is re-lowered only if missing or older than the newest
python source under python/compile/ (make also guards at the target level).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import sgd_apply as sgd_apply_mod
from .kernels import topk_ef
from .params import BLOCK

# palette of compression ratios the HLO compress path is compiled for;
# DeCo's delta* is snapped to this palette when the PJRT compressor is used
# (the rust hot path supports arbitrary delta natively).
DELTA_PALETTE = [0.01, 0.02, 0.05, 0.1, 0.2, 0.5]
# demo/compare dimension for the standalone compress + apply modules
COMPRESS_DIM = 65536

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_grad_module(mdef: model_mod.ModelDef) -> str:
    p = jax.ShapeDtypeStruct((mdef.spec.total,), jnp.float32)
    x = jax.ShapeDtypeStruct(mdef.x_shape, DTYPES[mdef.x_dtype])
    y = jax.ShapeDtypeStruct(mdef.y_shape, jnp.int32)

    def fn(params, xx, yy):
        loss, grad = mdef.loss_and_grad(params, xx, yy)
        return loss, grad

    return to_hlo_text(jax.jit(fn).lower(p, x, y))


def lower_compress_module(dim: int, delta: float) -> tuple[str, int]:
    k = topk_ef.k_for_delta(delta, BLOCK)
    g = jax.ShapeDtypeStruct((dim,), jnp.float32)
    e = jax.ShapeDtypeStruct((dim,), jnp.float32)

    def fn(gg, ee):
        return topk_ef.compress_ef(gg, ee, k=k, block=BLOCK)

    return to_hlo_text(jax.jit(fn).lower(g, e)), k


def lower_apply_module(dim: int) -> str:
    x = jax.ShapeDtypeStruct((dim,), jnp.float32)
    u = jax.ShapeDtypeStruct((dim,), jnp.float32)
    lr = jax.ShapeDtypeStruct((1,), jnp.float32)

    def fn(xx, uu, ll):
        return (sgd_apply_mod.sgd_apply(xx, uu, ll),)

    return to_hlo_text(jax.jit(fn).lower(x, u, lr))


def delta_tag(delta: float) -> str:
    return f"{delta:g}".replace(".", "p")


def build_artifacts(out_dir: Path, models: list[str] | None = None,
                    force: bool = False, verbose: bool = True) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    src_dir = Path(__file__).parent
    stamp = max(p.stat().st_mtime for p in src_dir.rglob("*.py"))

    def stale(path: Path) -> bool:
        return force or not path.exists() or path.stat().st_mtime < stamp \
            or path.stat().st_size == 0

    registry = model_mod.build_registry()
    if models:
        registry = {k: v for k, v in registry.items() if k in models}

    # merge with an existing manifest so partial (--models) builds never
    # drop entries for artifacts that are already on disk
    mpath_prev = out_dir / "manifest.json"
    manifest: dict = {"block": BLOCK, "modules": {}, "models": {}}
    if mpath_prev.exists():
        try:
            prev = json.loads(mpath_prev.read_text())
            if prev.get("block") == BLOCK:
                for sect in ("modules", "models"):
                    for name, entry in prev.get(sect, {}).items():
                        keep = sect == "models" or (
                            out_dir / entry.get("file", "")).exists()
                        if keep:
                            manifest[sect][name] = entry
        except (json.JSONDecodeError, OSError):
            pass

    for name, mdef in registry.items():
        fname = f"grad_{name}.hlo.txt"
        path = out_dir / fname
        if stale(path):
            if verbose:
                print(f"[aot] lowering grad_{name} (P={mdef.spec.total}) ...",
                      flush=True)
            path.write_text(lower_grad_module(mdef))
        manifest["modules"][f"grad_{name}"] = {
            "file": fname, "kind": "grad", "model": name,
            "inputs": [
                {"name": "params", "shape": [mdef.spec.total], "dtype": "f32"},
                {"name": "x", "shape": list(mdef.x_shape),
                 "dtype": mdef.x_dtype},
                {"name": "y", "shape": list(mdef.y_shape), "dtype": "i32"},
            ],
            "outputs": [
                {"name": "loss", "shape": [], "dtype": "f32"},
                {"name": "grad", "shape": [mdef.spec.total], "dtype": "f32"},
            ],
        }
        manifest["models"][name] = {
            "task": mdef.task,
            "param_count": mdef.spec.total,
            "batch": mdef.batch,
            "x_shape": list(mdef.x_shape),
            "x_dtype": mdef.x_dtype,
            "y_shape": list(mdef.y_shape),
            "grad_bits": mdef.spec.total * 32,
            "meta": mdef.meta,
            "tensors": mdef.spec.to_manifest(),
        }

    # palette compress modules (pallas L1 lowered into HLO)
    for delta in DELTA_PALETTE:
        fname = f"compress_{COMPRESS_DIM}_{delta_tag(delta)}.hlo.txt"
        path = out_dir / fname
        k = topk_ef.k_for_delta(delta, BLOCK)
        if stale(path):
            if verbose:
                print(f"[aot] lowering compress d={COMPRESS_DIM} "
                      f"delta={delta} (k={k}) ...", flush=True)
            text, k = lower_compress_module(COMPRESS_DIM, delta)
            path.write_text(text)
        manifest["modules"][f"compress_{delta_tag(delta)}"] = {
            "file": fname, "kind": "compress", "dim": COMPRESS_DIM,
            "delta": delta, "block": BLOCK, "k_per_block": k,
            "inputs": [
                {"name": "g", "shape": [COMPRESS_DIM], "dtype": "f32"},
                {"name": "e", "shape": [COMPRESS_DIM], "dtype": "f32"},
            ],
            "outputs": [
                {"name": "delta_vec", "shape": [COMPRESS_DIM], "dtype": "f32"},
                {"name": "e_new", "shape": [COMPRESS_DIM], "dtype": "f32"},
            ],
        }

    # fused sgd apply
    fname = f"sgd_apply_{COMPRESS_DIM}.hlo.txt"
    path = out_dir / fname
    if stale(path):
        if verbose:
            print(f"[aot] lowering sgd_apply d={COMPRESS_DIM} ...", flush=True)
        path.write_text(lower_apply_module(COMPRESS_DIM))
    manifest["modules"]["sgd_apply"] = {
        "file": fname, "kind": "apply", "dim": COMPRESS_DIM,
        "inputs": [
            {"name": "x", "shape": [COMPRESS_DIM], "dtype": "f32"},
            {"name": "upd", "shape": [COMPRESS_DIM], "dtype": "f32"},
            {"name": "lr", "shape": [1], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "x_new", "shape": [COMPRESS_DIM], "dtype": "f32"},
        ],
    }

    mpath = out_dir / "manifest.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    if verbose:
        total = sum((out_dir / m["file"]).stat().st_size
                    for m in manifest["modules"].values())
        print(f"[aot] {len(manifest['modules'])} modules, "
              f"{total / 1e6:.1f} MB of HLO text, manifest -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output dir")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of models to lower (default: all)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build_artifacts(Path(args.out), args.models, args.force)


if __name__ == "__main__":
    main()
