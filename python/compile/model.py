"""L2: the paper's training workloads as flat-parameter JAX models.

The paper evaluates CNN@FashionMNIST, CNN@CIFAR-10, ViT@ImageNet and
GPT@Wikitext (Sec. 5.1 / C.2). We implement the same three architectures —
the paper's exact 2conv+2fc CNN, a ViT, and a decoder-only GPT — each exposed
through ONE interface that the rust coordinator consumes via PJRT:

    loss_and_grad : (params f32[P], x, y) -> (loss f32[], grad f32[P])

P is padded to a multiple of params.BLOCK so the L1 blockwise compressor and
the rust hot path never need a remainder path. `aot.py` lowers one
`grad_<model>` HLO module per (model, batch) and records the tensor layout in
artifacts/manifest.json so rust can initialize parameters without python.

Model sizes are configurable; the registry at the bottom defines the variants
the experiments use (tiny ones for tests; the paper-scale gradient sizes are
what `timesim` uses for the time model).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .params import ParamSpec


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def attention(x: jnp.ndarray, wqkv: jnp.ndarray, wo: jnp.ndarray,
              n_head: int, causal: bool) -> jnp.ndarray:
    """Multi-head self-attention. x: [B,T,D], wqkv: [D,3D], wo: [D,D]."""
    B, T, D = x.shape
    hd = D // n_head
    qkv = x @ wqkv  # [B,T,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B,T,D] -> [B,H,T,hd]
        return t.reshape(B, T, n_head, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # [B,H,T,T]
    if causal:
        mask = jnp.tril(jnp.ones((T, T), dtype=bool))
        att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ wo


def transformer_block(x, p, prefix: str, n_head: int,
                      causal: bool) -> jnp.ndarray:
    ln1g, ln1b = p[f"{prefix}/ln1_g"], p[f"{prefix}/ln1_b"]
    ln2g, ln2b = p[f"{prefix}/ln2_g"], p[f"{prefix}/ln2_b"]
    x = x + attention(layer_norm(x, ln1g, ln1b), p[f"{prefix}/wqkv"],
                      p[f"{prefix}/wo"], n_head, causal)
    h = layer_norm(x, ln2g, ln2b)
    h = jax.nn.gelu(h @ p[f"{prefix}/w1"] + p[f"{prefix}/b1"])
    return x + h @ p[f"{prefix}/w2"] + p[f"{prefix}/b2"]


def _add_block_params(spec: ParamSpec, prefix: str, d: int, ff: int) -> None:
    spec.add(f"{prefix}/ln1_g", (d,), "ones")
    spec.add(f"{prefix}/ln1_b", (d,), "zeros")
    spec.add(f"{prefix}/wqkv", (d, 3 * d))
    spec.add(f"{prefix}/wo", (d, d))
    spec.add(f"{prefix}/ln2_g", (d,), "ones")
    spec.add(f"{prefix}/ln2_b", (d,), "zeros")
    spec.add(f"{prefix}/w1", (d, ff))
    spec.add(f"{prefix}/b1", (ff,), "zeros")
    spec.add(f"{prefix}/w2", (ff, d))
    spec.add(f"{prefix}/b2", (d,), "zeros")


# ---------------------------------------------------------------------------
# CNN — the paper's 2 conv + 2 fc architecture (Sec. C.2)
# ---------------------------------------------------------------------------

@dataclass
class CnnConfig:
    height: int = 28
    width: int = 28
    channels: int = 1
    classes: int = 10
    c1: int = 16
    c2: int = 32
    hidden: int = 128

    def build_spec(self) -> ParamSpec:
        s = ParamSpec()
        s.add("conv1/w", (3, 3, self.channels, self.c1))
        s.add("conv1/b", (self.c1,), "zeros")
        s.add("conv2/w", (3, 3, self.c1, self.c2))
        s.add("conv2/b", (self.c2,), "zeros")
        fh, fw = self.height // 4, self.width // 4
        s.add("fc1/w", (fh * fw * self.c2, self.hidden))
        s.add("fc1/b", (self.hidden,), "zeros")
        s.add("fc2/w", (self.hidden, self.classes))
        s.add("fc2/b", (self.classes,), "zeros")
        return s.finalize()


def _conv2d(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(cfg: CnnConfig, spec: ParamSpec, flat: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    p = spec.unflatten(flat)
    h = jax.nn.relu(_conv2d(x, p["conv1/w"], p["conv1/b"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv2d(h, p["conv2/w"], p["conv2/b"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1/w"] + p["fc1/b"])
    return h @ p["fc2/w"] + p["fc2/b"]


# ---------------------------------------------------------------------------
# ViT (Sec. 5.1: ViT-Base in the paper; size configurable here)
# ---------------------------------------------------------------------------

@dataclass
class VitConfig:
    image: int = 32
    channels: int = 3
    patch: int = 4
    d_model: int = 64
    n_layer: int = 2
    n_head: int = 4
    ff: int = 128
    classes: int = 10

    @property
    def n_patch(self) -> int:
        return (self.image // self.patch) ** 2

    def build_spec(self) -> ParamSpec:
        s = ParamSpec()
        pd = self.patch * self.patch * self.channels
        s.add("embed/w", (pd, self.d_model))
        s.add("embed/b", (self.d_model,), "zeros")
        s.add("cls", (1, 1, self.d_model), std=0.02)
        s.add("pos", (1, self.n_patch + 1, self.d_model), std=0.02)
        for i in range(self.n_layer):
            _add_block_params(s, f"blk{i}", self.d_model, self.ff)
        s.add("head/ln_g", (self.d_model,), "ones")
        s.add("head/ln_b", (self.d_model,), "zeros")
        s.add("head/w", (self.d_model, self.classes))
        s.add("head/b", (self.classes,), "zeros")
        return s.finalize()


def vit_forward(cfg: VitConfig, spec: ParamSpec, flat: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    p = spec.unflatten(flat)
    B = x.shape[0]
    g = cfg.image // cfg.patch
    # [B,H,W,C] -> [B, n_patch, patch*patch*C]
    xp = x.reshape(B, g, cfg.patch, g, cfg.patch, cfg.channels)
    xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(B, g * g, -1)
    h = xp @ p["embed/w"] + p["embed/b"]
    cls = jnp.broadcast_to(p["cls"], (B, 1, cfg.d_model))
    h = jnp.concatenate([cls, h], axis=1) + p["pos"]
    for i in range(cfg.n_layer):
        h = transformer_block(h, p, f"blk{i}", cfg.n_head, causal=False)
    h = layer_norm(h[:, 0], p["head/ln_g"], p["head/ln_b"])
    return h @ p["head/w"] + p["head/b"]


# ---------------------------------------------------------------------------
# GPT (decoder-only; paper uses GPT-2 small 124M)
# ---------------------------------------------------------------------------

@dataclass
class GptConfig:
    vocab: int = 512
    seq: int = 128
    d_model: int = 128
    n_layer: int = 2
    n_head: int = 4
    ff: int = 512

    def build_spec(self) -> ParamSpec:
        s = ParamSpec()
        s.add("wte", (self.vocab, self.d_model), std=0.02)
        s.add("wpe", (self.seq, self.d_model), std=0.02)
        for i in range(self.n_layer):
            _add_block_params(s, f"blk{i}", self.d_model, self.ff)
        s.add("ln_f/g", (self.d_model,), "ones")
        s.add("ln_f/b", (self.d_model,), "zeros")
        return s.finalize()


def gpt_forward(cfg: GptConfig, spec: ParamSpec, flat: jnp.ndarray,
                tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: i32[B, T] -> logits f32[B, T, vocab] (tied embedding head)."""
    p = spec.unflatten(flat)
    h = p["wte"][tokens] + p["wpe"][None, : tokens.shape[1]]
    for i in range(cfg.n_layer):
        h = transformer_block(h, p, f"blk{i}", cfg.n_head, causal=True)
    h = layer_norm(h, p["ln_f/g"], p["ln_f/b"])
    return h @ p["wte"].T


# ---------------------------------------------------------------------------
# model registry — ties everything together for aot.py and the tests
# ---------------------------------------------------------------------------

@dataclass
class ModelDef:
    name: str
    task: str  # "image" | "lm"
    spec: ParamSpec
    loss_and_grad: Callable  # (flat, x, y) -> (loss, grad)
    batch: int
    x_shape: Tuple[int, ...]
    x_dtype: str
    y_shape: Tuple[int, ...]
    meta: dict


def _image_model(name: str, cfg, fwd, batch: int, extra: dict) -> ModelDef:
    spec = cfg.build_spec()

    def loss_fn(flat, x, y):
        return cross_entropy(fwd(cfg, spec, flat, x), y)

    def loss_and_grad(flat, x, y):
        return jax.value_and_grad(loss_fn)(flat, x, y)

    h, w, c = (cfg.height, cfg.width, cfg.channels) \
        if isinstance(cfg, CnnConfig) else (cfg.image, cfg.image, cfg.channels)
    return ModelDef(
        name=name, task="image", spec=spec, loss_and_grad=loss_and_grad,
        batch=batch, x_shape=(batch, h, w, c), x_dtype="f32",
        y_shape=(batch,),
        meta={"classes": cfg.classes, **extra})


def _gpt_model(name: str, cfg: GptConfig, batch: int) -> ModelDef:
    spec = cfg.build_spec()

    def loss_fn(flat, x, y):
        logits = gpt_forward(cfg, spec, flat, x)
        return cross_entropy(logits, y)

    def loss_and_grad(flat, x, y):
        return jax.value_and_grad(loss_fn)(flat, x, y)

    return ModelDef(
        name=name, task="lm", spec=spec, loss_and_grad=loss_and_grad,
        batch=batch, x_shape=(batch, cfg.seq), x_dtype="i32",
        y_shape=(batch, cfg.seq),
        meta={"vocab": cfg.vocab, "seq": cfg.seq, "d_model": cfg.d_model,
              "n_layer": cfg.n_layer, "dataset": "synthetic-wikitext"})


def build_registry() -> Dict[str, ModelDef]:
    """All model variants. Keep tiny ones first — they drive the tests."""
    reg: Dict[str, ModelDef] = {}

    # paper's CNN on FashionMNIST-shaped and CIFAR-10-shaped inputs
    reg["cnn_fmnist"] = _image_model(
        "cnn_fmnist", CnnConfig(28, 28, 1, 10), cnn_forward, batch=32,
        extra={"dataset": "synthetic-fmnist"})
    reg["cnn_cifar"] = _image_model(
        "cnn_cifar", CnnConfig(32, 32, 3, 10), cnn_forward, batch=32,
        extra={"dataset": "synthetic-cifar10"})

    # ViT (tiny stand-in for ViT-Base; paper-scale S_g handled by timesim)
    reg["vit_tiny"] = _image_model(
        "vit_tiny", VitConfig(32, 3, 4, 64, 2, 4, 128, 10), vit_forward,
        batch=16, extra={"dataset": "synthetic-imagenet32"})

    # GPT variants: mini for fast loops, small for the e2e example
    reg["gpt_mini"] = _gpt_model(
        "gpt_mini", GptConfig(vocab=512, seq=64, d_model=128, n_layer=2,
                              n_head=4, ff=512), batch=8)
    reg["gpt_small"] = _gpt_model(
        "gpt_small", GptConfig(vocab=512, seq=128, d_model=256, n_layer=4,
                               n_head=8, ff=1024), batch=4)
    return reg


def numerical_grad(loss_fn, flat: np.ndarray, x, y, idx, eps=1e-3):
    """Central-difference gradient at selected indices (test oracle)."""
    out = []
    for i in idx:
        fp = flat.copy(); fp[i] += eps
        fm = flat.copy(); fm[i] -= eps
        out.append((float(loss_fn(fp, x, y)) - float(loss_fn(fm, x, y)))
                   / (2 * eps))
    return np.array(out)
